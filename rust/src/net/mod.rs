//! Simulated cluster + network cost model (testbed substitute, DESIGN.md §2).
//!
//! The paper's experiments run on 2–64 GPU nodes over 200 Gbps HPC fabric
//! and on a bandwidth-controlled 10–10000 Mbps two-node link (Fig 10).
//! Here, ranks are in-process workers; every collective *really moves the
//! bytes* (so numerics are exact) while time is charged by a deterministic
//! α–β model per link class:
//!
//! ```text
//! t(transfer of B bytes) = α_link + B / β_link
//! ```
//!
//! with separate (α, β) for intra-node (NVLink/Infinity-fabric class) and
//! inter-node (network class) links. Determinism is deliberate: the paper
//! itself refrains from comparing replicator wall-clocks because HPC
//! congestion makes timings unreliable; the simulator removes that noise
//! while preserving every relative claim (volume × schedule).
//!
//! `TrafficMatrix` additionally records who-sent-how-much-to-whom, which
//! regenerates the paper's Appendix-A communication-pattern figure
//! (`figures -- fig7`).
//!
//! ## Time substrate
//!
//! Two clocks live here:
//!
//! * [`SimClock`] — the original barrier-synchronous global clock (kept
//!   for `--no-overlap` parity and unit tests);
//! * [`Timeline`] — a set of per-lane ready-times (one lane per rank per
//!   resource) that the event engine in `train::engine` schedules onto;
//!   the engine keeps one per resource class (compute, intra-node
//!   fabric, inter-node NIC). Lanes only ever move forward: `reserve`
//!   places work at `max(earliest, lane_ready)` and advances the lane to
//!   the end of the reservation, so per-rank timelines are monotone by
//!   construction (property-tested below).
//!
//! [`ClusterModel`] adds scenario diversity on top of the homogeneous
//! α–β [`NetModel`]: per-node straggler slowdown factors (multiplying
//! compute durations) and per-node NIC bandwidth overrides (a group's
//! inter-node transfers run at the slowest member NIC).

use std::sync::Mutex;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Rank addressing: `rank = node * accels_per_node + accel`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub accels_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, accels_per_node: usize) -> Topology {
        assert!(nodes >= 1 && accels_per_node >= 1);
        Topology {
            nodes,
            accels_per_node,
        }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.accels_per_node
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.accels_per_node
    }

    pub fn accel_of(&self, rank: usize) -> usize {
        rank % self.accels_per_node
    }

    pub fn rank(&self, node: usize, accel: usize) -> usize {
        debug_assert!(node < self.nodes && accel < self.accels_per_node);
        node * self.accels_per_node + accel
    }

    /// The sharding group S of a rank: all ranks on the same node.
    pub fn shard_group(&self, rank: usize) -> Vec<usize> {
        let node = self.node_of(rank);
        (0..self.accels_per_node)
            .map(|a| self.rank(node, a))
            .collect()
    }

    /// The replication group R of a rank: the same accelerator index on
    /// every node (paper Appendix A: "accelerator 0 of node 0 replicates
    /// to accelerator 0 of node 1").
    pub fn repl_group(&self, rank: usize) -> Vec<usize> {
        let accel = self.accel_of(rank);
        (0..self.nodes).map(|n| self.rank(n, accel)).collect()
    }

    /// Link class between two ranks.
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.node_of(a) == self.node_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Slowest link class spanned by a group (a group containing two
    /// different nodes pays inter-node cost).
    pub fn group_link_class(&self, group: &[usize]) -> LinkClass {
        let first = self.node_of(group[0]);
        if group.iter().all(|&r| self.node_of(r) == first) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    IntraNode,
    InterNode,
}

/// α–β parameters for the two link classes + compute throughput.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Intra-node bandwidth, bytes/s (e.g. MI250x infinity fabric 50 GB/s).
    pub intra_bw: f64,
    /// Inter-node bandwidth, bytes/s (200 Gbps = 25 GB/s in the HPC runs;
    /// 10 Mbps..10 Gbps in the Fig 10 sweep).
    pub inter_bw: f64,
    /// Per-message latency (s).
    pub intra_lat: f64,
    pub inter_lat: f64,
    /// Modeled accelerator throughput for the compute-time part of the
    /// step clock, FLOP/s.
    pub device_flops: f64,
}

impl NetModel {
    /// The paper's HPC testbed class: fast fabric both levels.
    pub fn hpc() -> NetModel {
        NetModel {
            intra_bw: 50e9,
            inter_bw: 25e9,
            intra_lat: 5e-6,
            inter_lat: 20e-6,
            device_flops: 100e12,
        }
    }

    /// Fig 10 controlled-bandwidth testbed: 2 nodes, throttled network.
    pub fn throttled(inter_mbps: f64) -> NetModel {
        NetModel {
            inter_bw: inter_mbps * 1e6 / 8.0,
            ..NetModel::hpc()
        }
    }

    /// Paper-regime model for a scaled-down stand-in (DESIGN.md §2).
    ///
    /// Our substitute models are `s = paper_params / params` times smaller
    /// than the paper's, so every payload and every compute phase shrinks
    /// by `s`. Keeping bandwidths and device FLOP/s at the paper's testbed
    /// values and dividing the per-message latencies by `s` makes every
    /// simulated time exactly `t_paper / s` — all *ratios* between
    /// schemes (the reproduction target) are preserved bit-for-bit:
    ///   t_sim = α/s + (B/s)/bw = (α + B/bw)/s.
    ///
    /// Testbed constants: A100-class node (≈110 TFLOP/s sustained),
    /// NVLink-class intra-node (300 GB/s, 3 µs), 2×dual-port HDR
    /// inter-node (400 Gbit/s = 50 GB/s, 20 µs) — the paper's OLMo2 rig.
    pub fn paper_scaled(params: usize, paper_params: f64) -> NetModel {
        let s = (paper_params / params.max(1) as f64).max(1.0);
        NetModel {
            intra_bw: 300e9,
            inter_bw: 50e9,
            intra_lat: 3e-6 / s,
            inter_lat: 20e-6 / s,
            device_flops: 110e12,
        }
    }

    /// Override the inter-node bandwidth (Fig 10 throttling) keeping the
    /// rest of the model.
    pub fn with_inter_mbps(mut self, mbps: f64) -> NetModel {
        self.inter_bw = mbps * 1e6 / 8.0;
        self
    }

    pub fn bw(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraNode => self.intra_bw,
            LinkClass::InterNode => self.inter_bw,
        }
    }

    pub fn lat(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::IntraNode => self.intra_lat,
            LinkClass::InterNode => self.inter_lat,
        }
    }

    /// α–β time of one message of `bytes` over a link class.
    pub fn xfer_time(&self, class: LinkClass, bytes: u64) -> SimTime {
        self.lat(class) + bytes as f64 / self.bw(class)
    }

    /// Modeled compute time for `flops` on one accelerator.
    pub fn compute_time(&self, flops: f64) -> SimTime {
        flops / self.device_flops
    }
}

/// Per-(src-node, dst-node) byte counters + totals. Thread-safe; shared by
/// all collectives in a run.
#[derive(Debug)]
pub struct TrafficMatrix {
    nodes: usize,
    /// bytes[src_node * nodes + dst_node]; diagonal = intra-node traffic.
    bytes: Mutex<Vec<u64>>,
}

impl TrafficMatrix {
    pub fn new(nodes: usize) -> TrafficMatrix {
        TrafficMatrix {
            nodes,
            bytes: Mutex::new(vec![0; nodes * nodes]),
        }
    }

    pub fn record(&self, src_node: usize, dst_node: usize, bytes: u64) {
        let mut m = self.bytes.lock().unwrap();
        m[src_node * self.nodes + dst_node] += bytes;
    }

    pub fn snapshot(&self) -> Vec<u64> {
        self.bytes.lock().unwrap().clone()
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Total bytes that crossed node boundaries (the scarce resource).
    pub fn inter_node_bytes(&self) -> u64 {
        let m = self.bytes.lock().unwrap();
        let mut total = 0;
        for s in 0..self.nodes {
            for d in 0..self.nodes {
                if s != d {
                    total += m[s * self.nodes + d];
                }
            }
        }
        total
    }

    /// Total intra-node bytes (diagonal).
    pub fn intra_node_bytes(&self) -> u64 {
        let m = self.bytes.lock().unwrap();
        (0..self.nodes).map(|i| m[i * self.nodes + i]).sum()
    }

    pub fn reset(&self) {
        self.bytes.lock().unwrap().fill(0);
    }

    /// Restore counters from a prior [`TrafficMatrix::snapshot`]
    /// (checkpoint restore).
    pub fn restore(&self, snapshot: &[u64]) -> anyhow::Result<()> {
        let mut m = self.bytes.lock().unwrap();
        anyhow::ensure!(
            snapshot.len() == m.len(),
            "traffic snapshot has {} cells, matrix has {}",
            snapshot.len(),
            m.len()
        );
        m.copy_from_slice(snapshot);
        Ok(())
    }

    /// Render as the Appendix-A-style traffic matrix (fig7).
    pub fn render(&self) -> String {
        let m = self.bytes.lock().unwrap();
        let mut out = String::from("src\\dst ");
        for d in 0..self.nodes {
            out.push_str(&format!("{:>12}", format!("node{d}")));
        }
        out.push('\n');
        for s in 0..self.nodes {
            out.push_str(&format!("node{s:<4}"));
            for d in 0..self.nodes {
                out.push_str(&format!("{:>12}", crate::util::fmt_bytes(m[s * self.nodes + d])));
            }
            out.push('\n');
        }
        out
    }
}

/// A monotonically-advancing simulated clock. Collectives advance it by
/// the *maximum* across participants (bulk-synchronous steps); compute
/// phases advance it by the slowest rank.
#[derive(Debug, Default)]
pub struct SimClock {
    now: Mutex<SimTime>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }

    pub fn now(&self) -> SimTime {
        *self.now.lock().unwrap()
    }

    pub fn advance(&self, dt: SimTime) -> SimTime {
        let mut t = self.now.lock().unwrap();
        *t += dt.max(0.0);
        *t
    }

    pub fn reset(&self) {
        *self.now.lock().unwrap() = 0.0;
    }
}

/// Per-node scenario knobs layered over the homogeneous [`NetModel`]:
/// straggler compute-slowdown factors and NIC bandwidth overrides.
/// Empty vectors mean "uniform cluster" — the event engine then matches
/// the legacy cost model bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterModel {
    /// `slowdown[node]` multiplies every compute duration on that node
    /// (1.0 = nominal; 2.0 = half-speed straggler). Shorter than `nodes`
    /// is fine: missing entries default to 1.0.
    pub slowdown: Vec<f64>,
    /// Per-node NIC bandwidth override in bytes/s (0.0 or missing =
    /// use `NetModel::inter_bw`). An inter-node transfer runs at the
    /// minimum bandwidth across the nodes it touches.
    pub node_inter_bw: Vec<f64>,
}

impl ClusterModel {
    pub fn uniform() -> ClusterModel {
        ClusterModel::default()
    }

    pub fn is_uniform(&self) -> bool {
        self.slowdown.iter().all(|&s| s == 1.0)
            && self.node_inter_bw.iter().all(|&b| b == 0.0)
    }

    /// Compute-slowdown factor of a node (≥ 1.0 nominal; values below
    /// 1.0 are allowed and model a faster-than-nominal node).
    pub fn slowdown_of(&self, node: usize) -> f64 {
        match self.slowdown.get(node) {
            Some(&s) if s > 0.0 => s,
            _ => 1.0,
        }
    }

    /// Effective NIC bandwidth of one node under `net`.
    pub fn node_bw(&self, net: &NetModel, node: usize) -> f64 {
        match self.node_inter_bw.get(node) {
            Some(&b) if b > 0.0 => b,
            _ => net.inter_bw,
        }
    }

    /// Effective bandwidth for a transfer over `class` touching `nodes`
    /// (inter-node = slowest member NIC; intra-node is never overridden).
    pub fn group_bw(&self, net: &NetModel, class: LinkClass, nodes: &[usize]) -> f64 {
        match class {
            LinkClass::IntraNode => net.intra_bw,
            LinkClass::InterNode => nodes
                .iter()
                .map(|&n| self.node_bw(net, n))
                .fold(net.inter_bw, f64::min),
        }
    }

    /// Derive each node's staleness budget (`--staleness auto`) from its
    /// simulated compute/NIC profile: the number of *local* steps the
    /// node's sync transfer spans,
    ///
    /// ```text
    /// S_n = clamp(ceil(xfer_n / step_n), 1, period − 1)
    /// xfer_n = inter_lat + gather_bytes / node_bw(n)
    /// step_n = compute_time(step_flops) · slowdown(n)
    /// ```
    ///
    /// so a node behind a slow NIC tolerates a larger S (the transfer
    /// needs more steps to hide), while a compute straggler's long steps
    /// absorb the same transfer in fewer of them — its arrival deadline
    /// lands earlier in step count, which is what lets the fast nodes'
    /// contributions reach it in time. `gather_bytes` is the caller's
    /// estimate of the per-node send volume (payload × (group − 1) for
    /// the naive all-gather).
    pub fn auto_staleness(
        &self,
        net: &NetModel,
        nodes: usize,
        step_flops: f64,
        gather_bytes: u64,
        period: u64,
    ) -> Vec<u64> {
        let max_s = period.saturating_sub(1);
        if max_s == 0 {
            // period 1 leaves no room for an in-flight window: every
            // step syncs, so the only consistent derivation is the
            // synchronous S = 0 everywhere.
            return vec![0; nodes];
        }
        (0..nodes)
            .map(|n| {
                let step = (net.compute_time(step_flops) * self.slowdown_of(n)).max(1e-30);
                let xfer = net.inter_lat + gather_bytes as f64 / self.node_bw(net, n);
                ((xfer / step).ceil() as u64).clamp(1, max_s)
            })
            .collect()
    }

    /// Parse "NODE:FACTOR[,NODE:FACTOR...]" into a slowdown table.
    pub fn parse_slowdown(spec: &str) -> anyhow::Result<Vec<f64>> {
        parse_node_table(spec, 1.0)
    }

    /// Parse "NODE:MBPS[,NODE:MBPS...]" into a bytes/s NIC table.
    pub fn parse_node_mbps(spec: &str) -> anyhow::Result<Vec<f64>> {
        let mut t = parse_node_table(spec, 0.0)?;
        for b in t.iter_mut() {
            *b *= 1e6 / 8.0; // Mbps → bytes/s
        }
        Ok(t)
    }
}

/// Largest node index accepted in a NODE:VALUE spec — bounds the table
/// allocation against typo'd inputs (the simulator tops out far below
/// this anyway).
const MAX_SPEC_NODE: usize = 65_536;

fn parse_node_table(spec: &str, fill: f64) -> anyhow::Result<Vec<f64>> {
    let mut table = Vec::new();
    if spec.trim().is_empty() {
        return Ok(table);
    }
    for part in spec.split(',') {
        let (node, value) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad entry {part:?}, want NODE:VALUE"))?;
        let node: usize = node.trim().parse()?;
        anyhow::ensure!(
            node < MAX_SPEC_NODE,
            "node index {node} out of range (max {MAX_SPEC_NODE})"
        );
        let value: f64 = value.trim().parse()?;
        anyhow::ensure!(value > 0.0, "value for node {node} must be positive");
        if table.len() <= node {
            table.resize(node + 1, fill);
        }
        table[node] = value;
    }
    Ok(table)
}

/// One membership transition, taking effect at the *start* of its step.
///
/// Transitions are node-granular: a node's accelerators enter and leave
/// the cluster together (the intra-node shard group is never split).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    /// The node (re)enters the active set. Current params are
    /// broadcast-in from node 0 before it contributes again.
    Join,
    /// The node departs cleanly: it stops computing and is excluded from
    /// every subsequent sync group, but keeps its local state, so a
    /// later [`MembershipEvent::Join`] resumes from it.
    Leave,
    /// The node dies: as `Leave`, but its optimizer moments, replicator
    /// residuals, and carried windows are lost. A later `Join` restores
    /// them from the last checkpoint when `--checkpoint-dir` is set,
    /// from fresh state otherwise.
    Crash,
}

impl MembershipEvent {
    pub fn label(self) -> &'static str {
        match self {
            MembershipEvent::Join => "join",
            MembershipEvent::Leave => "leave",
            MembershipEvent::Crash => "crash",
        }
    }

    fn parse(s: &str) -> anyhow::Result<MembershipEvent> {
        match s.trim() {
            "join" => Ok(MembershipEvent::Join),
            "leave" => Ok(MembershipEvent::Leave),
            "crash" => Ok(MembershipEvent::Crash),
            other => anyhow::bail!("unknown membership event {other:?}, want join|leave|crash"),
        }
    }
}

/// A deterministic, node-granularity membership timeline (`--churn`,
/// `--crash`): which nodes are active at each training step.
///
/// Events fire at step *boundaries* — an event at step `s` takes effect
/// before any work of step `s` is scheduled — so runs are exactly
/// reproducible from the spec string alone. Node 0 is the permanent
/// anchor (the params source for validation and join broadcasts) and can
/// never leave or crash; [`MembershipTimeline::validate`] rejects
/// timelines that try. An empty timeline is the fixed-group path.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MembershipTimeline {
    /// `(step, node, event)`, kept sorted by `(step, node)`.
    events: Vec<(u64, usize, MembershipEvent)>,
}

impl MembershipTimeline {
    pub fn new() -> MembershipTimeline {
        MembershipTimeline::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// All events in `(step, node)` order.
    pub fn events(&self) -> &[(u64, usize, MembershipEvent)] {
        &self.events
    }

    fn push(&mut self, step: u64, node: usize, ev: MembershipEvent) {
        self.events.push((step, node, ev));
        self.events.sort_by_key(|&(s, n, _)| (s, n));
    }

    /// Parse and append a `--churn` spec: `EVENT:NODE@STEP[,...]`, e.g.
    /// `leave:1@4,join:1@8,crash:2@6`. Syntax is checked here; semantic
    /// validity (ranges, ordering) is checked by
    /// [`MembershipTimeline::validate`] once the mesh size is known.
    pub fn add_churn_spec(&mut self, spec: &str) -> anyhow::Result<()> {
        if spec.trim().is_empty() {
            return Ok(());
        }
        for part in spec.split(',') {
            let bad =
                || anyhow::anyhow!("bad churn entry {part:?}, want EVENT:NODE@STEP (e.g. leave:1@4)");
            let (ev, rest) = part.split_once(':').ok_or_else(bad)?;
            let ev = MembershipEvent::parse(ev)?;
            let (node, step) = rest.split_once('@').ok_or_else(bad)?;
            let node: usize = node
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad node in churn entry {part:?}: {e}"))?;
            anyhow::ensure!(
                node < MAX_SPEC_NODE,
                "node index {node} out of range (max {MAX_SPEC_NODE})"
            );
            let step: u64 = step
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad step in churn entry {part:?}: {e}"))?;
            self.push(step, node, ev);
        }
        Ok(())
    }

    /// Parse and append a `--crash` shorthand: `NODE@STEP[:REJOIN][,...]`.
    /// The node crashes at the start of `STEP`; with `:REJOIN` it also
    /// rejoins (from checkpoint, when `--checkpoint-dir` is set) at the
    /// start of `REJOIN`.
    pub fn add_crash_spec(&mut self, spec: &str) -> anyhow::Result<()> {
        if spec.trim().is_empty() {
            return Ok(());
        }
        for part in spec.split(',') {
            let bad = || {
                anyhow::anyhow!("bad crash entry {part:?}, want NODE@STEP or NODE@STEP:REJOIN")
            };
            let (node, rest) = part.split_once('@').ok_or_else(bad)?;
            let node: usize = node
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad node in crash entry {part:?}: {e}"))?;
            anyhow::ensure!(
                node < MAX_SPEC_NODE,
                "node index {node} out of range (max {MAX_SPEC_NODE})"
            );
            let (step, rejoin) = match rest.split_once(':') {
                Some((s, r)) => (s, Some(r)),
                None => (rest, None),
            };
            let step: u64 = step
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad step in crash entry {part:?}: {e}"))?;
            self.push(step, node, MembershipEvent::Crash);
            if let Some(r) = rejoin {
                let r: u64 = r
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad rejoin step in crash entry {part:?}: {e}"))?;
                anyhow::ensure!(
                    r > step,
                    "crash entry {part:?}: rejoin step {r} must come after the crash step {step}"
                );
                self.push(r, node, MembershipEvent::Join);
            }
        }
        Ok(())
    }

    /// Semantic validation against a concrete mesh and run length:
    /// every event's node must exist and not be the node-0 anchor, its
    /// step must fall inside the run, at most one event per `(node,
    /// step)`, and the whole timeline must replay as a legal state
    /// machine (leave/crash only while active, join only while inactive).
    pub fn validate(&self, nodes: usize, steps: u64) -> anyhow::Result<()> {
        for w in self.events.windows(2) {
            let (s0, n0, e0) = w[0];
            let (s1, n1, e1) = w[1];
            anyhow::ensure!(
                (s0, n0) != (s1, n1),
                "overlapping membership events for node {n0} at step {s0} ({} and {}): \
                 at most one join/leave/crash per node per step",
                e0.label(),
                e1.label()
            );
        }
        let mut active = vec![true; nodes];
        for &(step, node, ev) in &self.events {
            anyhow::ensure!(
                node < nodes,
                "membership event {}:{node}@{step}: node {node} out of range \
                 (cluster has {nodes} nodes)",
                ev.label()
            );
            anyhow::ensure!(
                node != 0,
                "membership event {}:{node}@{step}: node 0 is the permanent anchor \
                 (params source for validation and join broadcasts) and cannot churn; \
                 pick a node >= 1",
                ev.label()
            );
            anyhow::ensure!(
                step < steps,
                "membership event {}:{node}@{step}: step {step} is past the end of \
                 the run (steps = {steps})",
                ev.label()
            );
            match ev {
                MembershipEvent::Join => {
                    anyhow::ensure!(
                        !active[node],
                        "membership event join:{node}@{step}: node {node} is already \
                         active at step {step}"
                    );
                    active[node] = true;
                }
                MembershipEvent::Leave | MembershipEvent::Crash => {
                    anyhow::ensure!(
                        active[node],
                        "membership event {}:{node}@{step}: node {node} is already \
                         inactive at step {step}",
                        ev.label()
                    );
                    active[node] = false;
                }
            }
        }
        Ok(())
    }

    /// The active-node mask at `step`, after applying every event with
    /// `event_step <= step`.
    pub fn active_at(&self, step: u64, nodes: usize) -> Vec<bool> {
        let mut active = vec![true; nodes];
        for &(s, node, ev) in &self.events {
            if s > step {
                break;
            }
            if node < nodes {
                active[node] = matches!(ev, MembershipEvent::Join);
            }
        }
        active
    }

    /// The events that fire at exactly `step`, in node order.
    pub fn events_at(&self, step: u64) -> Vec<(usize, MembershipEvent)> {
        self.events
            .iter()
            .filter(|&&(s, _, _)| s == step)
            .map(|&(_, n, ev)| (n, ev))
            .collect()
    }

    /// Canonical spec string (round-trips through
    /// [`MembershipTimeline::add_churn_spec`]).
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|&(s, n, ev)| format!("{}:{n}@{s}", ev.label()))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Render an active-node mask as the steps-CSV `membership` bitmask
/// (`"1011"` = four nodes, node 2 inactive).
pub fn membership_label(active: &[bool]) -> String {
    active.iter().map(|&a| if a { '1' } else { '0' }).collect()
}

/// What one simulated transfer attempt suffers on its way across the
/// inter-node links (`--link-fault`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOutcome {
    /// The attempt crosses intact.
    Delivered,
    /// The attempt is lost in flight: the sender learns about it only
    /// through its per-attempt timeout.
    Dropped,
    /// The attempt arrives bit-flipped: the receiver's payload checksum
    /// catches it at decode and the sender retries.
    Corrupted,
}

/// One `--link-fault` failure mode on one (possibly wildcarded) directed
/// node-pair link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Each attempt is lost independently with probability `p`.
    Drop { p: f64 },
    /// Each attempt is bit-flipped independently with probability `p`.
    Corrupt { p: f64 },
    /// The link is fully down for steps in `[from, to)` — every attempt
    /// during the window drops.
    Flap { from: u64, to: u64 },
    /// The link runs at `factor` of its nominal bandwidth (0 < factor
    /// ≤ 1): every attempt's duration is divided by `factor`.
    Degrade { factor: f64 },
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Drop { .. } => "drop",
            FaultKind::Corrupt { .. } => "corrupt",
            FaultKind::Flap { .. } => "flap",
            FaultKind::Degrade { .. } => "degrade",
        }
    }
}

/// A [`FaultKind`] bound to a directed link: `None` endpoints are the
/// spec's `*` wildcard ("any node").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRule {
    pub kind: FaultKind,
    pub src: Option<usize>,
    pub dst: Option<usize>,
}

impl FaultRule {
    fn matches(&self, src: usize, dst: usize) -> bool {
        self.src.is_none_or(|s| s == src) && self.dst.is_none_or(|d| d == dst)
    }

    /// Whether this rule can affect any transfer at `step` (flaps are
    /// windowed; every other kind is permanent).
    fn active_at(&self, step: u64) -> bool {
        match self.kind {
            FaultKind::Flap { from, to } => (from..to).contains(&step),
            _ => true,
        }
    }
}

fn parse_fault_endpoint(s: &str) -> anyhow::Result<Option<usize>> {
    let s = s.trim();
    if s == "*" {
        return Ok(None);
    }
    let node: usize = s
        .parse()
        .map_err(|e| anyhow::anyhow!("bad node {s:?} in link-fault entry: {e}"))?;
    anyhow::ensure!(
        node < MAX_SPEC_NODE,
        "node index {node} out of range (max {MAX_SPEC_NODE})"
    );
    Ok(Some(node))
}

/// A deterministic link-fault timeline (`--link-fault`): which directed
/// inter-node links drop, corrupt, flap, or degrade, and when.
///
/// Per-attempt fault decisions are pure functions of `(experiment seed,
/// step, attempt, src, dst, rule index)` — no shared RNG stream is
/// consumed — so faulted runs are bit-reproducible from the spec and the
/// seed alone, and an empty timeline leaves the transfer schedule
/// untouched (prop-tested bit-identical in the integration suite).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultTimeline {
    rules: Vec<FaultRule>,
}

impl FaultTimeline {
    pub fn new() -> FaultTimeline {
        FaultTimeline::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    /// Parse and append a `--link-fault` spec: comma-joined
    /// `KIND:SRC-DST@PARAM` entries, e.g.
    /// `drop:0-2@p0.05,corrupt:1-3@p0.01,flap:2-0@40..90,degrade:3-*@0.25x`.
    /// Endpoints are node indices or `*`; parameters are `pP` (drop /
    /// corrupt probability), `A..B` (flap step window), or `Fx`
    /// (degrade bandwidth factor). Syntax is checked here; semantic
    /// validity against a concrete mesh is checked by
    /// [`FaultTimeline::validate`].
    pub fn add_spec(&mut self, spec: &str) -> anyhow::Result<()> {
        if spec.trim().is_empty() {
            return Ok(());
        }
        for part in spec.split(',') {
            let bad = || {
                anyhow::anyhow!(
                    "bad link-fault entry {part:?}, want KIND:SRC-DST@PARAM \
                     (e.g. drop:0-2@p0.05, flap:2-0@40..90, degrade:3-*@0.25x)"
                )
            };
            let (kind, rest) = part.split_once(':').ok_or_else(bad)?;
            let (link, param) = rest.split_once('@').ok_or_else(bad)?;
            let (src, dst) = link.split_once('-').ok_or_else(bad)?;
            let src = parse_fault_endpoint(src)?;
            let dst = parse_fault_endpoint(dst)?;
            let param = param.trim();
            let prob = |p: &str| -> anyhow::Result<f64> {
                let p = p.strip_prefix('p').ok_or_else(|| {
                    anyhow::anyhow!("bad probability {p:?} in {part:?}, want p0.05 style")
                })?;
                let p: f64 = p.parse()?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "probability {p} in {part:?} must be in [0, 1]"
                );
                Ok(p)
            };
            let kind = match kind.trim() {
                "drop" => FaultKind::Drop { p: prob(param)? },
                "corrupt" => FaultKind::Corrupt { p: prob(param)? },
                "flap" => {
                    let (from, to) = param.split_once("..").ok_or_else(|| {
                        anyhow::anyhow!("bad flap window {param:?} in {part:?}, want A..B")
                    })?;
                    let from: u64 = from.trim().parse()?;
                    let to: u64 = to.trim().parse()?;
                    anyhow::ensure!(
                        from < to,
                        "flap window {from}..{to} in {part:?} is empty"
                    );
                    FaultKind::Flap { from, to }
                }
                "degrade" => {
                    let f = param.strip_suffix('x').ok_or_else(|| {
                        anyhow::anyhow!("bad degrade factor {param:?} in {part:?}, want 0.25x style")
                    })?;
                    let factor: f64 = f.parse()?;
                    anyhow::ensure!(
                        factor > 0.0 && factor <= 1.0,
                        "degrade factor {factor} in {part:?} must be in (0, 1]"
                    );
                    FaultKind::Degrade { factor }
                }
                other => anyhow::bail!(
                    "unknown link-fault kind {other:?} in {part:?} (drop|corrupt|flap|degrade)"
                ),
            };
            self.rules.push(FaultRule { kind, src, dst });
        }
        Ok(())
    }

    /// Semantic validation against a concrete mesh: concrete endpoints
    /// must name existing nodes, and a rule must not pin both endpoints
    /// to the same node (there is no inter-node link from a node to
    /// itself).
    pub fn validate(&self, nodes: usize) -> anyhow::Result<()> {
        for rule in &self.rules {
            for endpoint in [rule.src, rule.dst].into_iter().flatten() {
                anyhow::ensure!(
                    endpoint < nodes,
                    "link-fault rule {}: node {endpoint} out of range (cluster has {nodes} nodes)",
                    self.render_rule(rule)
                );
            }
            if let (Some(s), Some(d)) = (rule.src, rule.dst) {
                anyhow::ensure!(
                    s != d,
                    "link-fault rule {}: src and dst are the same node (faults apply to \
                     inter-node links only)",
                    self.render_rule(rule)
                );
            }
        }
        Ok(())
    }

    fn render_rule(&self, rule: &FaultRule) -> String {
        let ep = |e: Option<usize>| e.map_or("*".to_string(), |n| n.to_string());
        let param = match rule.kind {
            FaultKind::Drop { p } | FaultKind::Corrupt { p } => format!("p{p}"),
            FaultKind::Flap { from, to } => format!("{from}..{to}"),
            FaultKind::Degrade { factor } => format!("{factor}x"),
        };
        format!(
            "{}:{}-{}@{}",
            rule.kind.label(),
            ep(rule.src),
            ep(rule.dst),
            param
        )
    }

    /// Canonical spec string (round-trips through
    /// [`FaultTimeline::add_spec`]).
    pub fn render(&self) -> String {
        self.rules
            .iter()
            .map(|r| self.render_rule(r))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Deterministic uniform draw in [0, 1) for one (rule, attempt, link)
    /// decision — a pure hash of its coordinates, so fault decisions never
    /// perturb any other RNG stream.
    fn roll(seed: u64, step: u64, attempt: u32, src: usize, dst: usize, rule_ix: usize) -> f64 {
        let h = seed
            ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (attempt as u64 + 1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
            ^ (((src as u64) << 32) | dst as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
            ^ (rule_ix as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F);
        let mut sm = crate::util::rng::SplitMix64::new(h);
        (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// The fate of one transfer attempt from node `src` to the peer set
    /// `dsts` at `(step, attempt)`. A transfer is lost if *any* traversed
    /// link drops it (flap windows drop unconditionally) and corrupted if
    /// any link flips it; loss takes precedence (a dropped attempt never
    /// arrives to fail its checksum).
    pub fn attempt_outcome(
        &self,
        seed: u64,
        step: u64,
        attempt: u32,
        src: usize,
        dsts: &[usize],
    ) -> FaultOutcome {
        if self.rules.is_empty() {
            return FaultOutcome::Delivered;
        }
        let mut corrupted = false;
        for &dst in dsts {
            if dst == src {
                continue;
            }
            for (ix, rule) in self.rules.iter().enumerate() {
                if !rule.matches(src, dst) {
                    continue;
                }
                match rule.kind {
                    FaultKind::Drop { p } => {
                        if p > 0.0 && Self::roll(seed, step, attempt, src, dst, ix) < p {
                            return FaultOutcome::Dropped;
                        }
                    }
                    FaultKind::Flap { .. } => {
                        if rule.active_at(step) {
                            return FaultOutcome::Dropped;
                        }
                    }
                    FaultKind::Corrupt { p } => {
                        if p > 0.0 && Self::roll(seed, step, attempt, src, dst, ix) < p {
                            corrupted = true;
                        }
                    }
                    FaultKind::Degrade { .. } => {}
                }
            }
        }
        if corrupted {
            FaultOutcome::Corrupted
        } else {
            FaultOutcome::Delivered
        }
    }

    /// Duration multiplier (≥ 1.0) for a transfer from `src` to `dsts` at
    /// `step`: the worst degraded link on the path sets the pace (its
    /// bandwidth factor divides into the nominal duration).
    pub fn slowdown(&self, step: u64, src: usize, dsts: &[usize]) -> f64 {
        let mut mult: f64 = 1.0;
        for &dst in dsts {
            if dst == src {
                continue;
            }
            for rule in &self.rules {
                if let FaultKind::Degrade { factor } = rule.kind {
                    if rule.matches(src, dst) && rule.active_at(step) {
                        mult = mult.max(1.0 / factor);
                    }
                }
            }
        }
        mult
    }

    /// Whether any fault rule can affect a `src → dsts` transfer at
    /// `step` (pre-check so the fault-free fast path skips per-attempt
    /// bookkeeping entirely).
    pub fn affects(&self, step: u64, src: usize, dsts: &[usize]) -> bool {
        dsts.iter().any(|&dst| {
            dst != src
                && self
                    .rules
                    .iter()
                    .any(|r| r.matches(src, dst) && r.active_at(step))
        })
    }

    /// Number of distinct directed inter-node links with at least one
    /// active fault rule at `step` (the steps-CSV `faulted_links`
    /// column).
    pub fn active_link_count(&self, step: u64, nodes: usize) -> u64 {
        let mut count = 0u64;
        for src in 0..nodes {
            for dst in 0..nodes {
                if src != dst
                    && self
                        .rules
                        .iter()
                        .any(|r| r.matches(src, dst) && r.active_at(step))
                {
                    count += 1;
                }
            }
        }
        count
    }
}

/// Monotone per-lane ready-times — the discrete-event substrate.
///
/// One lane per (rank, resource); the engine keeps one `Timeline` for
/// compute lanes and one for NIC lanes. All operations preserve the
/// invariant `ready[lane]` never decreases, and every reservation's
/// busy interval is accumulated per lane (for utilisation metrics).
#[derive(Clone, Debug)]
pub struct Timeline {
    ready: Vec<SimTime>,
    busy: Vec<f64>,
}

impl Timeline {
    pub fn new(lanes: usize) -> Timeline {
        Timeline {
            ready: vec![0.0; lanes],
            busy: vec![0.0; lanes],
        }
    }

    pub fn lanes(&self) -> usize {
        self.ready.len()
    }

    /// Current ready-time of a lane.
    pub fn now(&self, lane: usize) -> SimTime {
        self.ready[lane]
    }

    /// Latest ready-time across a set of lanes (join/max semantics —
    /// the earliest instant a collective over those lanes may start).
    pub fn join(&self, lanes: &[usize]) -> SimTime {
        lanes.iter().fold(0.0, |m, &l| m.max(self.ready[l]))
    }

    /// Latest ready-time across all lanes.
    pub fn horizon(&self) -> SimTime {
        self.ready.iter().fold(0.0, |m, &t| m.max(t))
    }

    /// Reserve `dur` on `lane` starting no earlier than `earliest`.
    /// Returns the (start, end) actually scheduled; the lane advances to
    /// `end` and its busy counter accumulates `dur`.
    pub fn reserve(&mut self, lane: usize, earliest: SimTime, dur: SimTime) -> (SimTime, SimTime) {
        let dur = dur.max(0.0);
        let start = self.ready[lane].max(earliest);
        let end = start + dur;
        self.ready[lane] = end;
        self.busy[lane] += dur;
        (start, end)
    }

    /// Push a lane's ready-time forward to at least `t` (a dependency
    /// stall — no busy time accumulates).
    pub fn stall_until(&mut self, lane: usize, t: SimTime) {
        if t > self.ready[lane] {
            self.ready[lane] = t;
        }
    }

    /// Busy time accumulated on a lane since construction / reset.
    pub fn busy(&self, lane: usize) -> f64 {
        self.busy[lane]
    }

    pub fn reset(&mut self) {
        self.ready.fill(0.0);
        self.busy.fill(0.0);
    }

    /// Snapshot every lane's `(ready, busy)` pair for checkpointing.
    pub fn export_state(&self) -> (Vec<SimTime>, Vec<f64>) {
        (self.ready.clone(), self.busy.clone())
    }

    /// Restore lanes from an [`Timeline::export_state`] snapshot taken on
    /// a timeline with the same lane count.
    pub fn import_state(&mut self, ready: Vec<SimTime>, busy: Vec<f64>) -> anyhow::Result<()> {
        anyhow::ensure!(
            ready.len() == self.ready.len() && busy.len() == self.busy.len(),
            "timeline snapshot has {} ready / {} busy lanes, timeline has {}",
            ready.len(),
            busy.len(),
            self.ready.len()
        );
        self.ready = ready;
        self.busy = busy;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_addressing() {
        let t = Topology::new(3, 4);
        assert_eq!(t.world_size(), 12);
        assert_eq!(t.node_of(7), 1);
        assert_eq!(t.accel_of(7), 3);
        assert_eq!(t.rank(1, 3), 7);
        for r in 0..t.world_size() {
            assert_eq!(t.rank(t.node_of(r), t.accel_of(r)), r);
        }
    }

    #[test]
    fn shard_group_is_intra_node() {
        let t = Topology::new(2, 4);
        assert_eq!(t.shard_group(5), vec![4, 5, 6, 7]);
        assert_eq!(t.group_link_class(&t.shard_group(5)), LinkClass::IntraNode);
    }

    #[test]
    fn repl_group_is_same_accel_across_nodes() {
        let t = Topology::new(3, 4);
        assert_eq!(t.repl_group(5), vec![1, 5, 9]);
        assert_eq!(t.group_link_class(&t.repl_group(5)), LinkClass::InterNode);
    }

    #[test]
    fn repl_and_shard_groups_partition_world() {
        // Every rank appears in exactly one S-group and one R-group slot.
        let t = Topology::new(4, 3);
        let mut seen = vec![0; t.world_size()];
        for n in 0..t.nodes {
            for &r in &t.shard_group(t.rank(n, 0)) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        let mut seen = vec![0; t.world_size()];
        for a in 0..t.accels_per_node {
            for &r in &t.repl_group(t.rank(0, a)) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn xfer_time_alpha_beta() {
        let m = NetModel {
            intra_bw: 100.0,
            inter_bw: 10.0,
            intra_lat: 1.0,
            inter_lat: 2.0,
            device_flops: 1e12,
        };
        assert!((m.xfer_time(LinkClass::IntraNode, 200) - 3.0).abs() < 1e-12);
        assert!((m.xfer_time(LinkClass::InterNode, 200) - 22.0).abs() < 1e-12);
    }

    #[test]
    fn throttled_scales_inter_only() {
        let m = NetModel::throttled(10.0); // 10 Mbps
        assert!((m.inter_bw - 1.25e6).abs() < 1.0);
        assert_eq!(m.intra_bw, NetModel::hpc().intra_bw);
    }

    #[test]
    fn paper_scaled_preserves_time_ratios() {
        // A model s× smaller with s×-smaller payloads must see the same
        // ratio between two transfer sizes as the paper-scale system.
        let paper = NetModel::paper_scaled(1_200_000_000, 1.2e9); // s = 1
        let ours = NetModel::paper_scaled(135_488, 1.2e9);
        let s = 1.2e9 / 135_488.0;
        let b_paper = 33_000_000u64; // 33 MB payload at paper scale
        let b_ours = (b_paper as f64 / s) as u64;
        let tp = paper.xfer_time(LinkClass::InterNode, b_paper);
        let to = ours.xfer_time(LinkClass::InterNode, b_ours);
        assert!((tp / to / s - 1.0).abs() < 0.01, "{}", tp / to / s);
    }

    #[test]
    fn with_inter_mbps_overrides_bandwidth_only() {
        let m = NetModel::paper_scaled(135_488, 1.2e9).with_inter_mbps(10.0);
        assert!((m.inter_bw - 1.25e6).abs() < 1.0);
        assert!(m.inter_lat < 1e-8); // scaled latency kept
    }

    #[test]
    fn traffic_matrix_accounting() {
        let tm = TrafficMatrix::new(2);
        tm.record(0, 1, 100);
        tm.record(1, 0, 50);
        tm.record(0, 0, 1000);
        assert_eq!(tm.inter_node_bytes(), 150);
        assert_eq!(tm.intra_node_bytes(), 1000);
        tm.reset();
        assert_eq!(tm.inter_node_bytes(), 0);
    }

    #[test]
    fn clock_monotone() {
        let c = SimClock::new();
        c.advance(1.5);
        c.advance(-3.0); // clamped
        assert!((c.now() - 1.5).abs() < 1e-12);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn traffic_render_contains_nodes() {
        let tm = TrafficMatrix::new(2);
        tm.record(0, 1, 2048);
        let s = tm.render();
        assert!(s.contains("node0") && s.contains("2.00 KiB"));
    }

    #[test]
    fn timeline_reserve_and_join() {
        let mut tl = Timeline::new(3);
        let (s, e) = tl.reserve(0, 0.0, 2.0);
        assert_eq!((s, e), (0.0, 2.0));
        // earliest below ready is clamped up
        let (s, e) = tl.reserve(0, 1.0, 1.0);
        assert_eq!((s, e), (2.0, 3.0));
        // earliest above ready wins (dependency wait, no busy time)
        let (s, e) = tl.reserve(1, 5.0, 0.5);
        assert_eq!((s, e), (5.0, 5.5));
        assert_eq!(tl.join(&[0, 1, 2]), 5.5);
        assert_eq!(tl.horizon(), 5.5);
        assert!((tl.busy(0) - 3.0).abs() < 1e-12);
        assert!((tl.busy(1) - 0.5).abs() < 1e-12);
        tl.stall_until(2, 9.0);
        assert_eq!(tl.now(2), 9.0);
        assert_eq!(tl.busy(2), 0.0);
        tl.stall_until(2, 1.0); // never moves backwards
        assert_eq!(tl.now(2), 9.0);
    }

    #[test]
    fn timeline_monotone_under_random_ops() {
        // Engine invariant: per-lane ready-times never decrease, whatever
        // interleaving of reservations/stalls the scheduler produces.
        crate::util::proptest::proptest(64, |g| {
            let lanes = g.usize(1, 6);
            let mut tl = Timeline::new(lanes);
            let mut prev: Vec<SimTime> = vec![0.0; lanes];
            for _ in 0..g.usize(1, 40) {
                let lane = g.usize(0, lanes - 1);
                let t = g.f32(0.0, 10.0) as f64;
                if g.bool() {
                    let (start, end) = tl.reserve(lane, t, g.f32(0.0, 3.0) as f64);
                    crate::util::proptest::prop_assert(start >= prev[lane], "start regressed");
                    crate::util::proptest::prop_assert(end >= start, "end before start");
                } else {
                    tl.stall_until(lane, t);
                }
                for l in 0..lanes {
                    crate::util::proptest::prop_assert(
                        tl.now(l) >= prev[l],
                        format!("lane {l} moved backwards"),
                    );
                    prev[l] = tl.now(l);
                }
            }
        });
    }

    #[test]
    fn cluster_model_defaults_are_uniform() {
        let c = ClusterModel::uniform();
        assert!(c.is_uniform());
        assert_eq!(c.slowdown_of(7), 1.0);
        let m = NetModel::hpc();
        assert_eq!(c.node_bw(&m, 3), m.inter_bw);
        assert_eq!(c.group_bw(&m, LinkClass::InterNode, &[0, 1]), m.inter_bw);
        assert_eq!(c.group_bw(&m, LinkClass::IntraNode, &[0]), m.intra_bw);
    }

    #[test]
    fn cluster_model_straggler_and_nic_overrides() {
        let c = ClusterModel {
            slowdown: ClusterModel::parse_slowdown("1:2.5").unwrap(),
            node_inter_bw: ClusterModel::parse_node_mbps("0:100").unwrap(),
        };
        assert!(!c.is_uniform());
        assert_eq!(c.slowdown_of(0), 1.0);
        assert_eq!(c.slowdown_of(1), 2.5);
        let m = NetModel::hpc();
        assert!((c.node_bw(&m, 0) - 12.5e6).abs() < 1.0);
        assert_eq!(c.node_bw(&m, 1), m.inter_bw);
        // group runs at the slowest member NIC
        assert!((c.group_bw(&m, LinkClass::InterNode, &[0, 1]) - 12.5e6).abs() < 1.0);
    }

    #[test]
    fn auto_staleness_tracks_nic_and_compute_profiles() {
        let net = NetModel {
            intra_bw: 1e9,
            inter_bw: 1000.0, // 1 KB/s: 4000 B gather = 4 s on the wire
            intra_lat: 0.0,
            inter_lat: 0.0,
            device_flops: 1e9, // 1e9 FLOP step = 1 s of compute
        };
        // Uniform cluster: every node spans ceil(4/1) = 4 steps.
        let c = ClusterModel::uniform();
        assert_eq!(c.auto_staleness(&net, 3, 1e9, 4000, 8), vec![4, 4, 4]);
        // A 4× compute straggler absorbs the transfer in 1 long step; a
        // node behind a 4×-slower NIC needs 16 (clamped to period − 1).
        let c = ClusterModel {
            slowdown: vec![1.0, 4.0],
            node_inter_bw: vec![0.0, 0.0, 250.0],
        };
        assert_eq!(c.auto_staleness(&net, 3, 1e9, 4000, 8), vec![4, 1, 7]);
        // S is always at least 1 and at most period − 1…
        assert_eq!(
            ClusterModel::uniform().auto_staleness(&net, 2, 1e15, 1, 2),
            vec![1, 1]
        );
        // …except at period 1, where no in-flight window can exist and
        // the derivation degrades to synchronous S = 0.
        assert_eq!(
            ClusterModel::uniform().auto_staleness(&net, 2, 1e9, 4000, 1),
            vec![0, 0]
        );
    }

    #[test]
    fn membership_timeline_parse_and_replay() {
        let mut t = MembershipTimeline::new();
        t.add_churn_spec("leave:1@4,join:1@8,crash:2@6").unwrap();
        assert!(!t.is_empty());
        t.validate(3, 20).unwrap();
        assert_eq!(t.active_at(0, 3), vec![true, true, true]);
        assert_eq!(t.active_at(4, 3), vec![true, false, true]);
        assert_eq!(t.active_at(6, 3), vec![true, false, false]);
        assert_eq!(t.active_at(8, 3), vec![true, true, false]);
        assert_eq!(t.events_at(6), vec![(2, MembershipEvent::Crash)]);
        assert_eq!(t.events_at(5), vec![]);
        // canonical render round-trips
        let mut t2 = MembershipTimeline::new();
        t2.add_churn_spec(&t.render()).unwrap();
        assert_eq!(t, t2);
        // empty timeline = fixed group
        let e = MembershipTimeline::new();
        assert!(e.is_empty());
        e.validate(2, 10).unwrap();
        assert_eq!(e.active_at(5, 2), vec![true, true]);
        assert_eq!(membership_label(&[true, false, true, true]), "1011");
    }

    #[test]
    fn membership_crash_shorthand() {
        let mut t = MembershipTimeline::new();
        t.add_crash_spec("1@6:12").unwrap();
        t.validate(2, 20).unwrap();
        assert_eq!(t.render(), "crash:1@6,join:1@12");
        let mut t = MembershipTimeline::new();
        t.add_crash_spec("1@6").unwrap();
        t.validate(2, 20).unwrap();
        assert_eq!(t.active_at(19, 2), vec![true, false]);
        // rejoin must come after the crash
        assert!(MembershipTimeline::new().add_crash_spec("1@6:6").is_err());
        assert!(MembershipTimeline::new().add_crash_spec("1@6:3").is_err());
    }

    #[test]
    fn membership_rejects_malformed_specs() {
        // syntax errors at parse time
        assert!(MembershipTimeline::new().add_churn_spec("nope").is_err());
        assert!(MembershipTimeline::new().add_churn_spec("evaporate:1@4").is_err());
        assert!(MembershipTimeline::new().add_churn_spec("leave:1").is_err());
        assert!(MembershipTimeline::new().add_churn_spec("leave:x@4").is_err());
        assert!(MembershipTimeline::new().add_churn_spec("leave:1@y").is_err());
        assert!(MembershipTimeline::new()
            .add_churn_spec("leave:4000000000@4")
            .is_err());
        assert!(MembershipTimeline::new().add_crash_spec("1").is_err());
        assert!(MembershipTimeline::new().add_crash_spec("z@4").is_err());
        // empty specs are no-ops
        let mut t = MembershipTimeline::new();
        t.add_churn_spec("").unwrap();
        t.add_crash_spec("  ").unwrap();
        assert!(t.is_empty());
    }

    #[test]
    fn membership_validate_rejects_semantic_errors() {
        let ok = |spec: &str| {
            let mut t = MembershipTimeline::new();
            t.add_churn_spec(spec).unwrap();
            t.validate(3, 10)
        };
        // node out of range
        assert!(ok("leave:7@4").is_err());
        // node 0 is the anchor
        assert!(ok("crash:0@4").is_err());
        assert!(ok("join:0@4").is_err());
        // step past the end of the run
        assert!(ok("leave:1@10").is_err());
        assert!(ok("leave:1@99").is_err());
        // overlapping events on one (node, step)
        assert!(ok("leave:1@4,join:1@4").is_err());
        // state-machine violations
        assert!(ok("join:1@4").is_err()); // already active
        assert!(ok("leave:1@2,crash:1@5").is_err()); // already gone
        assert!(ok("leave:1@2,join:1@5,join:1@7").is_err());
        // a legal double-churn replays fine
        assert!(ok("leave:1@2,join:1@5,leave:1@7").is_ok());
    }

    #[test]
    fn timeline_state_roundtrip() {
        let mut tl = Timeline::new(2);
        tl.reserve(0, 0.0, 2.0);
        tl.reserve(1, 5.0, 0.5);
        let (ready, busy) = tl.export_state();
        let mut tl2 = Timeline::new(2);
        tl2.import_state(ready, busy).unwrap();
        assert_eq!(tl2.now(0), 2.0);
        assert_eq!(tl2.now(1), 5.5);
        assert_eq!(tl2.busy(1), 0.5);
        // lane-count mismatch is rejected
        let (r, b) = tl.export_state();
        assert!(Timeline::new(3).import_state(r, b).is_err());
    }

    #[test]
    fn traffic_matrix_restore_roundtrip() {
        let tm = TrafficMatrix::new(2);
        tm.record(0, 1, 100);
        tm.record(0, 0, 7);
        let snap = tm.snapshot();
        let tm2 = TrafficMatrix::new(2);
        tm2.restore(&snap).unwrap();
        assert_eq!(tm2.inter_node_bytes(), 100);
        assert_eq!(tm2.intra_node_bytes(), 7);
        assert!(TrafficMatrix::new(3).restore(&snap).is_err());
    }

    #[test]
    fn fault_timeline_parse_and_query() {
        let mut t = FaultTimeline::new();
        t.add_spec("drop:0-2@p0.05,corrupt:1-3@p0.01,flap:2-0@40..90,degrade:3-*@0.25x")
            .unwrap();
        assert!(!t.is_empty());
        assert_eq!(t.rules().len(), 4);
        t.validate(4).unwrap();
        // canonical render round-trips
        let mut t2 = FaultTimeline::new();
        t2.add_spec(&t.render()).unwrap();
        assert_eq!(t, t2);
        // flap: link 2→0 is down exactly inside [40, 90)
        for (step, down) in [(39, false), (40, true), (89, true), (90, false)] {
            let out = t.attempt_outcome(7, step, 0, 2, &[0]);
            assert_eq!(out == FaultOutcome::Dropped, down, "step {step}");
        }
        // degrade: 3→anything runs at 0.25× bandwidth (4× duration);
        // untouched links stay nominal
        assert_eq!(t.slowdown(0, 3, &[0, 1]), 4.0);
        assert_eq!(t.slowdown(0, 1, &[0]), 1.0);
        // the affects pre-check matches the rules
        assert!(t.affects(0, 0, &[2]));
        assert!(!t.affects(0, 0, &[1]));
        assert!(t.affects(50, 2, &[0]));
        // active link count: 0→2, 1→3, 3→{0,1,2} always; 2→0 only while
        // flapping
        assert_eq!(t.active_link_count(0, 4), 5);
        assert_eq!(t.active_link_count(40, 4), 6);
        // empty timeline: everything delivered, nothing slowed
        let e = FaultTimeline::new();
        assert!(e.is_empty());
        e.validate(2).unwrap();
        assert_eq!(e.attempt_outcome(7, 0, 0, 0, &[1]), FaultOutcome::Delivered);
        assert_eq!(e.slowdown(0, 0, &[1]), 1.0);
        assert_eq!(e.active_link_count(0, 4), 0);
    }

    #[test]
    fn fault_decisions_are_deterministic_and_seed_sensitive() {
        let mut t = FaultTimeline::new();
        t.add_spec("drop:*-*@p0.5").unwrap();
        // same coordinates → same outcome, every time
        for step in 0..50 {
            for attempt in 0..3 {
                let a = t.attempt_outcome(11, step, attempt, 0, &[1]);
                let b = t.attempt_outcome(11, step, attempt, 0, &[1]);
                assert_eq!(a, b);
            }
        }
        // p=0.5 actually fires sometimes and spares sometimes
        let outcomes: Vec<FaultOutcome> =
            (0..64).map(|s| t.attempt_outcome(11, s, 0, 0, &[1])).collect();
        assert!(outcomes.contains(&FaultOutcome::Dropped));
        assert!(outcomes.contains(&FaultOutcome::Delivered));
        // a different seed draws a different pattern
        let other: Vec<FaultOutcome> =
            (0..64).map(|s| t.attempt_outcome(12, s, 0, 0, &[1])).collect();
        assert_ne!(outcomes, other);
        // attempts draw independently: a retry after a drop can succeed
        let mut t1 = FaultTimeline::new();
        t1.add_spec("drop:0-1@p1,corrupt:0-1@p1").unwrap();
        // p = 1: always dropped (loss shadows corruption)
        assert_eq!(t1.attempt_outcome(3, 0, 0, 0, &[1]), FaultOutcome::Dropped);
        let mut t2 = FaultTimeline::new();
        t2.add_spec("corrupt:0-1@p1").unwrap();
        assert_eq!(t2.attempt_outcome(3, 0, 0, 0, &[1]), FaultOutcome::Corrupted);
        // p = 0 never fires
        let mut t0 = FaultTimeline::new();
        t0.add_spec("drop:0-1@p0").unwrap();
        for s in 0..32 {
            assert_eq!(t0.attempt_outcome(3, s, 0, 0, &[1]), FaultOutcome::Delivered);
        }
    }

    #[test]
    fn fault_timeline_rejects_malformed_and_semantic_errors() {
        let parse = |spec: &str| {
            let mut t = FaultTimeline::new();
            t.add_spec(spec).map(|()| t)
        };
        // syntax
        assert!(parse("nope").is_err());
        assert!(parse("evaporate:0-1@p0.5").is_err());
        assert!(parse("drop:0-1").is_err());
        assert!(parse("drop:01@p0.5").is_err());
        assert!(parse("drop:0-1@0.5").is_err()); // missing 'p'
        assert!(parse("drop:0-1@p1.5").is_err()); // p out of range
        assert!(parse("flap:0-1@90..40").is_err()); // empty window
        assert!(parse("flap:0-1@40").is_err());
        assert!(parse("degrade:0-1@0.25").is_err()); // missing 'x'
        assert!(parse("degrade:0-1@0x").is_err()); // factor out of range
        assert!(parse("degrade:0-1@2x").is_err());
        assert!(parse("drop:4000000000-1@p0.5").is_err());
        // empty specs are no-ops
        let t = parse("  ").unwrap();
        assert!(t.is_empty());
        // semantics against the mesh
        let t = parse("drop:0-7@p0.5").unwrap();
        assert!(t.validate(4).is_err());
        let t = parse("drop:1-1@p0.5").unwrap();
        assert!(t.validate(4).is_err());
        let t = parse("drop:*-1@p0.5,degrade:1-*@0.5x").unwrap();
        t.validate(4).unwrap();
    }

    #[test]
    fn cluster_model_parse_rejects_garbage() {
        assert!(ClusterModel::parse_slowdown("1:0").is_err());
        assert!(ClusterModel::parse_slowdown("nope").is_err());
        assert!(ClusterModel::parse_slowdown("1:abc").is_err());
        // typo'd huge node index errors instead of allocating gigabytes
        assert!(ClusterModel::parse_slowdown("4000000000:2.0").is_err());
        assert_eq!(ClusterModel::parse_slowdown("").unwrap(), Vec::<f64>::new());
        // sparse spec fills the gaps with the neutral value
        let t = ClusterModel::parse_slowdown("2:3.0").unwrap();
        assert_eq!(t, vec![1.0, 1.0, 3.0]);
    }
}
