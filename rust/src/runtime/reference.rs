//! Reference backend (default build): a pure-Rust surrogate model.
//!
//! The surrogate is a *stochastic quadratic well*: for a model with flat
//! parameters θ and a per-model target θ\* (derived deterministically from
//! the manifest), one fwd+bwd returns
//!
//! ```text
//! g_i   = (θ_i − θ*_i) + σ·ε_i(batch)      ε deterministic in the batch
//! loss  = mean_i ½·g_i²
//! ```
//!
//! This keeps everything the coordinator studies *real*: gradients differ
//! per data stream (so replicas diverge without sync, DiLoCo drifts, and
//! compressed replication loses information), loss decreases under any of
//! the optimizers, and results are bit-deterministic in (params, batch) —
//! while needing no PJRT, no artifacts, and no network. `ModelRuntime` is
//! `Send + Sync` (plain data), which is what lets the trainer run
//! per-stream fwd/bwd on `std::thread::scope` workers.
//!
//! Models named `synthetic-*` are manufactured via
//! [`Manifest::synthetic`]; any other name still loads its
//! `<name>.meta.json` manifest from the artifacts dir if present, so the
//! figure benches run (with surrogate numerics) on a checkout that has
//! artifacts but no XLA toolchain.

use anyhow::{bail, Context, Result};

use super::{hash_name, BatchData, BatchDtype, Manifest};
use crate::util::rng::Rng;

/// Gradient noise scale σ of the surrogate (fraction of the deviation
/// term; large enough that compression/averaging effects are visible).
const NOISE_STD: f32 = 0.05;

/// Placeholder for compiled-HLO artifacts — only the PJRT backend can
/// execute them. Kept so `Runtime::load_hlo` has a stable signature.
pub struct Artifact {
    pub n_outputs: usize,
}

impl Artifact {
    pub fn execute_vec(&self, _input: &[f32]) -> Result<Vec<Vec<f32>>> {
        bail!("HLO execution requires the `xla` cargo feature (PJRT backend)")
    }
}

/// The surrogate "executable": manifest + target point of the quadratic.
pub struct ModelRuntime {
    pub manifest: Manifest,
    /// θ\* (logical length, manifest order).
    target: Vec<f32>,
}

/// Backend handle (no external client to own).
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        log::info!("reference runtime up (pure-Rust surrogate; build with --features xla for PJRT)");
        Ok(Runtime)
    }

    /// HLO compilation is a PJRT-only capability.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<Artifact> {
        bail!(
            "cannot compile {path:?}: HLO artifacts require the `xla` cargo feature \
             (this build uses the pure-Rust reference runtime)"
        )
    }

    /// Load `name` from `dir` (manifest file), or manufacture it when the
    /// name is `synthetic-*`.
    pub fn load_model(&self, dir: &std::path::Path, name: &str) -> Result<ModelRuntime> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let manifest = if meta_path.exists() {
            let meta = std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {meta_path:?}"))?;
            Manifest::parse(&meta)?
        } else if name.starts_with("synthetic") {
            Manifest::synthetic(name)
        } else {
            bail!(
                "no artifact {meta_path:?} for model {name:?} — run `make artifacts`, \
                 or use a synthetic-* model name with the reference runtime"
            );
        };
        log::info!(
            "surrogate model {name}: {} params ({} tensors), batch {}x{}",
            manifest.param_count,
            manifest.params.len(),
            manifest.batch,
            manifest.seq
        );
        let target = target_of(&manifest);
        Ok(ModelRuntime { manifest, target })
    }
}

/// θ\* for a manifest: per-tensor seeded normals — fixed across the run,
/// identical on every node, independent of the experiment seed (the
/// *data*, not the init, is what varies with the seed).
fn target_of(manifest: &Manifest) -> Vec<f32> {
    let rng = Rng::new(hash_name(&manifest.name) ^ 0x7A95_EED5_0BAD_F00D);
    let total: usize = manifest.params.iter().map(|p| p.len()).sum();
    let mut target = Vec::with_capacity(total);
    for p in &manifest.params {
        let mut chunk = vec![0.0f32; p.len()];
        rng.split(hash_name(&p.name)).fill_normal(&mut chunk, 0.25);
        target.extend_from_slice(&chunk);
    }
    target
}

/// Deterministic content hash of a batch (FNV-1a over dtype-tagged bits).
fn hash_batch(batch: &[BatchData]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |word: u32| {
        for b in word.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    for data in batch {
        match data {
            BatchData::I32(v) => {
                mix(0x1111);
                for &x in v {
                    mix(x as u32);
                }
            }
            BatchData::F32(v) => {
                mix(0x2222);
                for &x in v {
                    mix(x.to_bits());
                }
            }
        }
    }
    h
}

impl ModelRuntime {
    /// Mirror the PJRT backend's argument validation so shape/dtype bugs
    /// fail identically under both backends.
    fn check_batch(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<()> {
        let m = &self.manifest;
        let total: usize = m.params.iter().map(|p| p.len()).sum();
        anyhow::ensure!(
            flat_params.len() >= total,
            "flat params too short: {} < {total}",
            flat_params.len()
        );
        anyhow::ensure!(
            batch.len() == m.batch_inputs.len(),
            "expected {} batch inputs, got {}",
            m.batch_inputs.len(),
            batch.len()
        );
        for (spec, data) in m.batch_inputs.iter().zip(batch) {
            anyhow::ensure!(
                data.len() == spec.len(),
                "batch input {} length {} != {}",
                spec.name,
                data.len(),
                spec.len()
            );
            let ok = matches!(
                (spec.dtype, data),
                (BatchDtype::I32, BatchData::I32(_)) | (BatchDtype::F32, BatchData::F32(_))
            );
            anyhow::ensure!(ok, "batch input {} dtype mismatch", spec.name);
        }
        Ok(())
    }

    /// One fwd+bwd: returns (loss, flat gradient in manifest order).
    /// The pad tail of an FSDP-padded buffer is ignored and the returned
    /// gradient is logical-length — same contract as the PJRT backend.
    pub fn train_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<(f32, Vec<f32>)> {
        self.check_batch(flat_params, batch)?;
        let n = self.target.len();
        let mut rng = Rng::new(hash_batch(batch) ^ hash_name(&self.manifest.name));
        let mut grads = Vec::with_capacity(n);
        let mut loss_acc = 0.0f64;
        for (&p, &t) in flat_params[..n].iter().zip(&self.target) {
            let g = (p - t) + NOISE_STD * rng.normal_f32(1.0);
            grads.push(g);
            loss_acc += 0.5 * (g as f64) * (g as f64);
        }
        Ok(((loss_acc / n.max(1) as f64) as f32, grads))
    }

    /// Loss only (validation): the noise-free well depth. Runs on the
    /// process-wide inline executor; the trainer's validation loop uses
    /// [`ModelRuntime::eval_step_pooled`] with its worker pool.
    pub fn eval_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<f32> {
        self.eval_step_pooled(flat_params, batch, crate::parallel::WorkerPool::inline())
    }

    /// Chunk-parallel eval: per-grid-chunk partial sums folded in chunk
    /// order, so the loss is bit-identical for any `--threads N` (the
    /// association is fixed by the grid, not by the worker count).
    ///
    /// Within each chunk, [`crate::parallel::lanes::sq_dev_half_sum`]
    /// stripes the f64 accumulation over four lane accumulators — a
    /// *documented reassociation* of the reduction (the one lane kernel
    /// that is not bit-identical to a sequential loop). Like the chunk
    /// grid itself, the lane association depends only on the chunk
    /// length, so the loss remains a pure function of the inputs —
    /// unchanged by `--threads N` — just with a fixed, different
    /// summation tree than a fully serial sweep.
    pub fn eval_step_pooled(
        &self,
        flat_params: &[f32],
        batch: &[BatchData],
        pool: &crate::parallel::WorkerPool,
    ) -> Result<f32> {
        self.check_batch(flat_params, batch)?;
        let n = self.target.len();
        let mut partials = Vec::new();
        let loss_acc = crate::parallel::sum_chunks(pool, n, &mut partials, |lo, hi| {
            crate::parallel::lanes::sq_dev_half_sum(&flat_params[lo..hi], &self.target[lo..hi])
        });
        Ok((loss_acc / n.max(1) as f64) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelRuntime {
        Runtime::cpu()
            .unwrap()
            .load_model(std::path::Path::new("no-such-dir"), "synthetic-lm")
            .unwrap()
    }

    fn batch_for(m: &Manifest, tag: i32) -> Vec<BatchData> {
        m.batch_inputs
            .iter()
            .map(|s| BatchData::I32(vec![tag; s.len()]))
            .collect()
    }

    #[test]
    fn synthetic_model_loads_without_artifacts() {
        let m = model();
        assert_eq!(m.manifest.name, "synthetic-lm");
        assert_eq!(m.target.len(), m.manifest.param_count);
    }

    #[test]
    fn unknown_model_fails_with_hint() {
        let err = Runtime::cpu()
            .unwrap()
            .load_model(std::path::Path::new("artifacts"), "no-such-model")
            .err()
            .expect("should fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts") && msg.contains("no-such-model"), "{msg}");
    }

    #[test]
    fn train_step_deterministic_and_batch_sensitive() {
        let m = model();
        let params = m.manifest.init_flat(1);
        let b1 = batch_for(&m.manifest, 1);
        let (l1, g1) = m.train_step(&params, &b1).unwrap();
        let (l2, g2) = m.train_step(&params, &b1).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        // a different batch gives a different stochastic gradient
        let (_, g3) = m.train_step(&params, &batch_for(&m.manifest, 2)).unwrap();
        assert_ne!(g1, g3);
        assert!(l1.is_finite() && g1.len() == m.manifest.param_count);
    }

    #[test]
    fn gradient_descent_reduces_eval_loss() {
        let m = model();
        let mut params = m.manifest.init_flat(7);
        let batch = batch_for(&m.manifest, 0);
        let before = m.eval_step(&params, &batch).unwrap();
        for step in 0..30 {
            let (_, g) = m
                .train_step(&params, &batch_for(&m.manifest, step))
                .unwrap();
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.3 * gi;
            }
        }
        let after = m.eval_step(&params, &batch).unwrap();
        assert!(after < before * 0.5, "no learning: {before} -> {after}");
    }

    #[test]
    fn pad_tail_is_ignored() {
        let m = model();
        let mut params = m.manifest.init_flat(1);
        let batch = batch_for(&m.manifest, 1);
        let (l1, g1) = m.train_step(&params, &batch).unwrap();
        params.extend_from_slice(&[123.0; 64]); // FSDP pad region
        let (l2, g2) = m.train_step(&params, &batch).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1.len(), g2.len());
    }

    #[test]
    fn bad_batches_rejected() {
        let m = model();
        let params = m.manifest.init_flat(1);
        let spec_len = m.manifest.batch_inputs[0].len();
        // wrong length
        let bad = vec![
            BatchData::I32(vec![0; spec_len - 1]),
            BatchData::I32(vec![0; spec_len]),
        ];
        assert!(m.train_step(&params, &bad).is_err());
        // wrong dtype
        let bad = vec![
            BatchData::F32(vec![0.0; spec_len]),
            BatchData::I32(vec![0; spec_len]),
        ];
        assert!(m.train_step(&params, &bad).is_err());
        // wrong arity
        assert!(m.train_step(&params, &[]).is_err());
        // short param buffer
        assert!(m
            .train_step(&params[..10], &batch_for(&m.manifest, 0))
            .is_err());
    }

    #[test]
    fn load_hlo_unsupported() {
        let err = Runtime::cpu()
            .unwrap()
            .load_hlo(std::path::Path::new("x.hlo.txt"))
            .err()
            .expect("unsupported");
        assert!(format!("{err:#}").contains("xla"));
    }
}
