//! PJRT backend: load and execute the AOT-compiled JAX/Pallas artifacts
//! (cargo feature `xla`).
//!
//! This is the Python↔Rust bridge (DESIGN.md §3): `python/compile/aot.py`
//! lowers each model's `train_step`/`eval_step` to **HLO text** + a JSON
//! manifest; this module compiles the HLO on the PJRT CPU client and
//! marshals flat f32/i32 buffers in and out of the executable on the
//! training hot path. Python is never on the training path.

use anyhow::{bail, Context, Result};

use super::{BatchData, BatchDtype, Manifest};

/// A compiled HLO artifact (train or eval entry point).
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Artifact {
    /// Execute with raw literals and unpack the output tuple.
    pub fn execute_raw(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let items = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if self.n_outputs > 0 {
            anyhow::ensure!(
                items.len() == self.n_outputs,
                "expected {} outputs, got {}",
                self.n_outputs,
                items.len()
            );
        }
        Ok(items)
    }

    /// Execute a single-vector-in / tuple-of-vectors-out artifact (the
    /// `dct_extract_*` cross-validation artifacts).
    pub fn execute_vec(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let lit = xla::Literal::vec1(input);
        let out = self.execute_raw(&[lit])?;
        out.iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
            })
            .collect()
    }
}

/// The manifest + compiled train/eval executables for one model config.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub train: Artifact,
    pub eval: Artifact,
}

/// Owns the PJRT CPU client. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// Compile one HLO-text file.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Artifact { exe, n_outputs: 0 })
    }

    /// Load manifest + train + eval artifacts for `name` from `dir`.
    pub fn load_model(&self, dir: &std::path::Path, name: &str) -> Result<ModelRuntime> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let meta = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&meta)?;
        let mut train = self.load_hlo(&dir.join(format!("{name}.train.hlo.txt")))?;
        train.n_outputs = 1 + manifest.params.len();
        let mut eval = self.load_hlo(&dir.join(format!("{name}.eval.hlo.txt")))?;
        eval.n_outputs = 1;
        log::info!(
            "loaded model {name}: {} params ({} tensors), batch {}x{}",
            manifest.param_count,
            manifest.params.len(),
            manifest.batch,
            manifest.seq
        );
        Ok(ModelRuntime {
            manifest,
            train,
            eval,
        })
    }
}

impl ModelRuntime {
    /// Build the literal argument list: parameters (from a flat buffer +
    /// manifest shapes) followed by batch inputs.
    fn marshal_args(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<Vec<xla::Literal>> {
        let m = &self.manifest;
        anyhow::ensure!(
            batch.len() == m.batch_inputs.len(),
            "expected {} batch inputs, got {}",
            m.batch_inputs.len(),
            batch.len()
        );
        let mut args = Vec::with_capacity(m.params.len() + batch.len());
        let mut offset = 0usize;
        for p in &m.params {
            let end = offset + p.len();
            anyhow::ensure!(end <= flat_params.len(), "flat params too short at {}", p.name);
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&flat_params[offset..end])
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape {}: {e:?}", p.name))?;
            args.push(lit);
            offset = end;
        }
        for (spec, data) in m.batch_inputs.iter().zip(batch) {
            anyhow::ensure!(
                data.len() == spec.len(),
                "batch input {} length {} != {}",
                spec.name,
                data.len(),
                spec.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (spec.dtype, data) {
                (BatchDtype::I32, BatchData::I32(v)) => xla::Literal::vec1(v.as_slice()),
                (BatchDtype::F32, BatchData::F32(v)) => xla::Literal::vec1(v.as_slice()),
                _ => bail!("batch input {} dtype mismatch", spec.name),
            }
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {}: {e:?}", spec.name))?;
            args.push(lit);
        }
        Ok(args)
    }

    /// One fwd+bwd: returns (loss, flat gradient in manifest order).
    /// `flat_params` may be longer than the logical parameter count (the
    /// trainer hands in the padded FSDP buffer); the pad tail is ignored
    /// and the returned gradient is logical-length.
    pub fn train_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<(f32, Vec<f32>)> {
        let args = self.marshal_args(flat_params, batch)?;
        let out = self.train.execute_raw(&args)?;
        let loss: f32 = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?[0];
        let total: usize = self.manifest.params.iter().map(|p| p.len()).sum();
        let mut grads = Vec::with_capacity(total);
        for (p, lit) in self.manifest.params.iter().zip(&out[1..]) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("grad {}: {e:?}", p.name))?;
            anyhow::ensure!(v.len() == p.len(), "grad {} len {}", p.name, v.len());
            grads.extend_from_slice(&v);
        }
        Ok((loss, grads))
    }

    /// Loss only (validation).
    pub fn eval_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<f32> {
        let args = self.marshal_args(flat_params, batch)?;
        let out = self.eval.execute_raw(&args)?;
        Ok(out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?[0])
    }

    /// API parity with the reference backend: PJRT executes the compiled
    /// eval artifact itself, so the pool is unused.
    pub fn eval_step_pooled(
        &self,
        flat_params: &[f32],
        batch: &[BatchData],
        _pool: &crate::parallel::WorkerPool,
    ) -> Result<f32> {
        self.eval_step(flat_params, batch)
    }
}
