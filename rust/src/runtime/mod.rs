//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the Python↔Rust bridge (DESIGN.md §3): `python/compile/aot.py`
//! lowers each model's `train_step`/`eval_step` to **HLO text** + a JSON
//! manifest; this module parses the manifest, initializes parameters in
//! Rust (python never owns runtime state), compiles the HLO on the PJRT
//! CPU client, and marshals flat f32/i32 buffers in and out of the
//! executable on the training hot path.
//!
//! HLO *text* (not serialized proto) is load-bearing: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{bail, Context, Result};

use crate::util::json::{self};
use crate::util::rng::Rng;

/// Parameter initializer description (mirrors model.py `init_spec`).
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

/// One named parameter tensor in artifact order.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Batch input dtype.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchDtype {
    I32,
    F32,
}

/// One batch input in artifact argument order (after the parameters).
#[derive(Clone, Debug)]
pub struct BatchInputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: BatchDtype,
}

impl BatchInputSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Concrete batch data matching a `BatchInputSpec`.
#[derive(Clone, Debug)]
pub enum BatchData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::I32(v) => v.len(),
            BatchData::F32(v) => v.len(),
        }
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub src_seq: usize,
    pub patch_dim: usize,
    pub batch: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub batch_inputs: Vec<BatchInputSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let usz = |key: &str| -> Result<usize> {
            j.req(key)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_usize()
                .with_context(|| format!("{key} not a usize"))
        };
        let str_field = |key: &str| -> Result<String> {
            Ok(j.req(key)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .with_context(|| format!("{key} not a string"))?
                .to_string())
        };

        let mut params = Vec::new();
        for p in j
            .req("params")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("params not an array")?
        {
            let name = p
                .req("name")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("param name")?
                .to_string();
            let shape: Vec<usize> = p
                .req("shape")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<_>>()?;
            let init_arr = p
                .req("init")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("init")?;
            let kind = init_arr[0].as_str().context("init kind")?;
            let init = match kind {
                "normal" => Init::Normal(init_arr[1].as_f64().context("std")? as f32),
                "zeros" => Init::Zeros,
                "ones" => Init::Ones,
                other => bail!("unknown init {other:?}"),
            };
            params.push(ParamSpec { name, shape, init });
        }

        let mut batch_inputs = Vec::new();
        for b in j
            .req("batch_inputs")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("batch_inputs")?
        {
            let name = b
                .req("name")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("batch name")?
                .to_string();
            let shape: Vec<usize> = b
                .req("shape")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("batch shape")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<_>>()?;
            let dtype = match b
                .req("dtype")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("dtype")?
            {
                "i32" => BatchDtype::I32,
                "f32" => BatchDtype::F32,
                other => bail!("unknown batch dtype {other:?}"),
            };
            batch_inputs.push(BatchInputSpec { name, shape, dtype });
        }

        Ok(Manifest {
            name: str_field("name")?,
            family: str_field("family")?,
            vocab: usz("vocab")?,
            d_model: usz("d_model")?,
            n_heads: usz("n_heads")?,
            n_layers: usz("n_layers")?,
            d_ff: usz("d_ff")?,
            seq: usz("seq")?,
            src_seq: usz("src_seq")?,
            patch_dim: usz("patch_dim")?,
            batch: usz("batch")?,
            param_count: usz("param_count")?,
            params,
            batch_inputs,
        })
    }

    /// Flat parameter ordering as (name, shape) pairs for `shard::FlatLayout`.
    pub fn flat_params(&self) -> Vec<(String, Vec<usize>)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.shape.clone()))
            .collect()
    }

    /// Initialize a flat parameter vector (manifest order) from the init
    /// specs. Deterministic in `seed`; every node calls this with the same
    /// seed so replicas start identical (as FSDP replicas do).
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let rng = Rng::new(seed);
        let total: usize = self.params.iter().map(|p| p.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for p in &self.params {
            let mut chunk = vec![0.0f32; p.len()];
            match p.init {
                Init::Normal(std) => rng.split(hash_name(&p.name)).fill_normal(&mut chunk, std),
                Init::Zeros => {}
                Init::Ones => chunk.fill(1.0),
            }
            flat.extend_from_slice(&chunk);
        }
        flat
    }

    /// Tokens (or patches) consumed per train step — the unit for the
    /// compute-time model.
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq.max(1)
    }

    /// Rough fwd+bwd FLOPs per step: the standard 6·N·T transformer
    /// estimate (used only by the simulated step clock, not numerics).
    pub fn step_flops(&self) -> f64 {
        6.0 * self.param_count as f64 * self.tokens_per_step() as f64
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a — stable across runs/platforms (std hasher is randomized).
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// A compiled HLO artifact (train or eval entry point).
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Artifact {
    /// Execute with raw literals and unpack the output tuple.
    pub fn execute_raw(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let items = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if self.n_outputs > 0 {
            anyhow::ensure!(
                items.len() == self.n_outputs,
                "expected {} outputs, got {}",
                self.n_outputs,
                items.len()
            );
        }
        Ok(items)
    }

    /// Execute a single-vector-in / tuple-of-vectors-out artifact (the
    /// `dct_extract_*` cross-validation artifacts).
    pub fn execute_vec(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let lit = xla::Literal::vec1(input);
        let out = self.execute_raw(&[lit])?;
        out.iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
            })
            .collect()
    }
}

/// The manifest + compiled train/eval executables for one model config.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub train: Artifact,
    pub eval: Artifact,
}

/// Owns the PJRT CPU client. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// Compile one HLO-text file.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Artifact { exe, n_outputs: 0 })
    }

    /// Load manifest + train + eval artifacts for `name` from `dir`.
    pub fn load_model(&self, dir: &std::path::Path, name: &str) -> Result<ModelRuntime> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let meta = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&meta)?;
        let mut train = self.load_hlo(&dir.join(format!("{name}.train.hlo.txt")))?;
        train.n_outputs = 1 + manifest.params.len();
        let mut eval = self.load_hlo(&dir.join(format!("{name}.eval.hlo.txt")))?;
        eval.n_outputs = 1;
        log::info!(
            "loaded model {name}: {} params ({} tensors), batch {}x{}",
            manifest.param_count,
            manifest.params.len(),
            manifest.batch,
            manifest.seq
        );
        Ok(ModelRuntime {
            manifest,
            train,
            eval,
        })
    }
}

impl ModelRuntime {
    /// Build the literal argument list: parameters (from a flat buffer +
    /// manifest shapes) followed by batch inputs.
    fn marshal_args(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<Vec<xla::Literal>> {
        let m = &self.manifest;
        anyhow::ensure!(
            batch.len() == m.batch_inputs.len(),
            "expected {} batch inputs, got {}",
            m.batch_inputs.len(),
            batch.len()
        );
        let mut args = Vec::with_capacity(m.params.len() + batch.len());
        let mut offset = 0usize;
        for p in &m.params {
            let end = offset + p.len();
            anyhow::ensure!(end <= flat_params.len(), "flat params too short at {}", p.name);
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&flat_params[offset..end])
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape {}: {e:?}", p.name))?;
            args.push(lit);
            offset = end;
        }
        for (spec, data) in m.batch_inputs.iter().zip(batch) {
            anyhow::ensure!(
                data.len() == spec.len(),
                "batch input {} length {} != {}",
                spec.name,
                data.len(),
                spec.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (spec.dtype, data) {
                (BatchDtype::I32, BatchData::I32(v)) => xla::Literal::vec1(v.as_slice()),
                (BatchDtype::F32, BatchData::F32(v)) => xla::Literal::vec1(v.as_slice()),
                _ => bail!("batch input {} dtype mismatch", spec.name),
            }
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {}: {e:?}", spec.name))?;
            args.push(lit);
        }
        Ok(args)
    }

    /// One fwd+bwd: returns (loss, flat gradient in manifest order).
    /// `flat_params` may be longer than the logical parameter count (the
    /// trainer hands in the padded FSDP buffer); the pad tail is ignored
    /// and the returned gradient is logical-length.
    pub fn train_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<(f32, Vec<f32>)> {
        let args = self.marshal_args(flat_params, batch)?;
        let out = self.train.execute_raw(&args)?;
        let loss: f32 = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?[0];
        let total: usize = self.manifest.params.iter().map(|p| p.len()).sum();
        let mut grads = Vec::with_capacity(total);
        for (p, lit) in self.manifest.params.iter().zip(&out[1..]) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("grad {}: {e:?}", p.name))?;
            anyhow::ensure!(v.len() == p.len(), "grad {} len {}", p.name, v.len());
            grads.extend_from_slice(&v);
        }
        Ok((loss, grads))
    }

    /// Loss only (validation).
    pub fn eval_step(&self, flat_params: &[f32], batch: &[BatchData]) -> Result<f32> {
        let args = self.marshal_args(flat_params, batch)?;
        let out = self.eval.execute_raw(&args)?;
        Ok(out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
      "name": "m", "family": "lm", "vocab": 8, "d_model": 4, "n_heads": 1,
      "n_layers": 1, "d_ff": 8, "seq": 4, "src_seq": 0, "patch_dim": 0,
      "batch": 2, "param_count": 20,
      "params": [
        {"name": "a", "shape": [2, 3], "init": ["normal", 0.02]},
        {"name": "b", "shape": [14], "init": ["ones"]}
      ],
      "batch_inputs": [
        {"name": "tokens", "shape": [2, 4], "dtype": "i32"}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        assert_eq!(m.name, "m");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![2, 3]);
        assert_eq!(m.params[0].init, Init::Normal(0.02));
        assert_eq!(m.params[1].init, Init::Ones);
        assert_eq!(m.batch_inputs[0].dtype, BatchDtype::I32);
        assert_eq!(m.tokens_per_step(), 8);
        assert!(m.step_flops() > 0.0);
    }

    #[test]
    fn init_flat_deterministic_and_respects_spec() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        let a = m.init_flat(7);
        let b = m.init_flat(7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        // "b" is all-ones
        assert!(a[6..].iter().all(|&x| x == 1.0));
        // normal part is not constant and scaled by std
        assert!(a[..6].iter().any(|&x| x != a[0]));
        assert!(a[..6].iter().all(|&x| x.abs() < 0.2));
        // different seeds differ
        assert_ne!(m.init_flat(8)[..6], a[..6]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        let bad = MINI_MANIFEST.replace("\"ones\"", "\"sevens\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn name_hash_stable() {
        assert_eq!(hash_name("embed/tok"), hash_name("embed/tok"));
        assert_ne!(hash_name("embed/tok"), hash_name("embed/pos"));
    }
}
