//! Model runtime: manifests + two interchangeable execution backends.
//!
//! The manifest layer (this file) is backend-independent: it parses
//! `<name>.meta.json`, owns parameter initialization in Rust (python never
//! holds runtime state), and describes batch inputs.
//!
//! Two backends provide `Runtime` / `ModelRuntime` / `Artifact`:
//!
//! * **`pjrt` (cargo feature `xla`)** — loads the AOT-compiled JAX/Pallas
//!   HLO-text artifacts produced by `python/compile/aot.py` and executes
//!   them on the PJRT CPU client. HLO *text* (not serialized proto) is
//!   load-bearing: jax ≥ 0.5 emits protos with 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! * **`reference` (default)** — a pure-Rust surrogate model: a noisy
//!   quadratic well in parameter space whose gradients are deterministic
//!   in (params, batch). It exercises every coordinator code path
//!   (sharding, collectives, replication, optimizers, the event engine)
//!   with real learning dynamics and zero external dependencies, so
//!   `cargo build && cargo test` pass offline. Models named
//!   `synthetic-*` are manufactured in-process without artifact files.
//!
//! Both backends expose the same API surface, checked by the trainer and
//! integration tests.

use anyhow::{bail, Context, Result};

use crate::util::json::{self};
use crate::util::rng::Rng;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{Artifact, ModelRuntime, Runtime};

#[cfg(not(feature = "xla"))]
mod reference;
#[cfg(not(feature = "xla"))]
pub use reference::{Artifact, ModelRuntime, Runtime};

/// Parameter initializer description (mirrors model.py `init_spec`).
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    Normal(f32),
    Zeros,
    Ones,
}

/// One named parameter tensor in artifact order.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Batch input dtype.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchDtype {
    I32,
    F32,
}

/// One batch input in artifact argument order (after the parameters).
#[derive(Clone, Debug)]
pub struct BatchInputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: BatchDtype,
}

impl BatchInputSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Concrete batch data matching a `BatchInputSpec`.
#[derive(Clone, Debug)]
pub enum BatchData {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::I32(v) => v.len(),
            BatchData::F32(v) => v.len(),
        }
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub src_seq: usize,
    pub patch_dim: usize,
    pub batch: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub batch_inputs: Vec<BatchInputSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let usz = |key: &str| -> Result<usize> {
            j.req(key)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_usize()
                .with_context(|| format!("{key} not a usize"))
        };
        let str_field = |key: &str| -> Result<String> {
            Ok(j.req(key)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .with_context(|| format!("{key} not a string"))?
                .to_string())
        };

        let mut params = Vec::new();
        for p in j
            .req("params")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("params not an array")?
        {
            let name = p
                .req("name")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("param name")?
                .to_string();
            let shape: Vec<usize> = p
                .req("shape")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<_>>()?;
            let init_arr = p
                .req("init")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("init")?;
            let kind = init_arr[0].as_str().context("init kind")?;
            let init = match kind {
                "normal" => Init::Normal(init_arr[1].as_f64().context("std")? as f32),
                "zeros" => Init::Zeros,
                "ones" => Init::Ones,
                other => bail!("unknown init {other:?}"),
            };
            params.push(ParamSpec { name, shape, init });
        }

        let mut batch_inputs = Vec::new();
        for b in j
            .req("batch_inputs")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .context("batch_inputs")?
        {
            let name = b
                .req("name")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("batch name")?
                .to_string();
            let shape: Vec<usize> = b
                .req("shape")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .context("batch shape")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<_>>()?;
            let dtype = match b
                .req("dtype")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .context("dtype")?
            {
                "i32" => BatchDtype::I32,
                "f32" => BatchDtype::F32,
                other => bail!("unknown batch dtype {other:?}"),
            };
            batch_inputs.push(BatchInputSpec { name, shape, dtype });
        }

        Ok(Manifest {
            name: str_field("name")?,
            family: str_field("family")?,
            vocab: usz("vocab")?,
            d_model: usz("d_model")?,
            n_heads: usz("n_heads")?,
            n_layers: usz("n_layers")?,
            d_ff: usz("d_ff")?,
            seq: usz("seq")?,
            src_seq: usz("src_seq")?,
            patch_dim: usz("patch_dim")?,
            batch: usz("batch")?,
            param_count: usz("param_count")?,
            params,
            batch_inputs,
        })
    }

    /// Manufacture a small causal-LM manifest in-process (no artifact
    /// files needed). Used by the reference backend for models named
    /// `synthetic-*`: the shapes are big enough that one FSDP shard is
    /// ~100 KiB — the regime where the paper's bandwidth claims bite —
    /// while a full fwd/bwd surrogate stays microseconds.
    pub fn synthetic(name: &str) -> Manifest {
        let (vocab, d_model, d_ff, seq, batch) = (256usize, 64usize, 128usize, 32usize, 8usize);
        let params = vec![
            ParamSpec {
                name: "embed/tok".into(),
                shape: vec![vocab, d_model],
                init: Init::Normal(0.02),
            },
            ParamSpec {
                name: "mlp/w1".into(),
                shape: vec![d_model, d_ff],
                init: Init::Normal(0.05),
            },
            ParamSpec {
                name: "mlp/w2".into(),
                shape: vec![d_ff, d_model],
                init: Init::Normal(0.05),
            },
            ParamSpec {
                name: "head/out".into(),
                shape: vec![d_model, vocab],
                init: Init::Normal(0.02),
            },
            ParamSpec {
                name: "head/bias".into(),
                shape: vec![vocab],
                init: Init::Zeros,
            },
        ];
        let param_count = params.iter().map(|p| p.len()).sum();
        Manifest {
            name: name.to_string(),
            family: "lm".into(),
            vocab,
            d_model,
            n_heads: 2,
            n_layers: 1,
            d_ff,
            seq,
            src_seq: 0,
            patch_dim: 0,
            batch,
            param_count,
            params,
            batch_inputs: vec![
                BatchInputSpec {
                    name: "tokens".into(),
                    shape: vec![batch, seq],
                    dtype: BatchDtype::I32,
                },
                BatchInputSpec {
                    name: "targets".into(),
                    shape: vec![batch, seq],
                    dtype: BatchDtype::I32,
                },
            ],
        }
    }

    /// Flat parameter ordering as (name, shape) pairs for `shard::FlatLayout`.
    pub fn flat_params(&self) -> Vec<(String, Vec<usize>)> {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.shape.clone()))
            .collect()
    }

    /// Initialize a flat parameter vector (manifest order) from the init
    /// specs. Deterministic in `seed`; every node calls this with the same
    /// seed so replicas start identical (as FSDP replicas do).
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let rng = Rng::new(seed);
        let total: usize = self.params.iter().map(|p| p.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for p in &self.params {
            let mut chunk = vec![0.0f32; p.len()];
            match p.init {
                Init::Normal(std) => rng.split(hash_name(&p.name)).fill_normal(&mut chunk, std),
                Init::Zeros => {}
                Init::Ones => chunk.fill(1.0),
            }
            flat.extend_from_slice(&chunk);
        }
        flat
    }

    /// Tokens (or patches) consumed per train step — the unit for the
    /// compute-time model.
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq.max(1)
    }

    /// Rough fwd+bwd FLOPs per step: the standard 6·N·T transformer
    /// estimate (used only by the simulated step clock, not numerics).
    pub fn step_flops(&self) -> f64 {
        6.0 * self.param_count as f64 * self.tokens_per_step() as f64
    }
}

pub(crate) fn hash_name(name: &str) -> u64 {
    // FNV-1a — stable across runs/platforms (std hasher is randomized).
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
      "name": "m", "family": "lm", "vocab": 8, "d_model": 4, "n_heads": 1,
      "n_layers": 1, "d_ff": 8, "seq": 4, "src_seq": 0, "patch_dim": 0,
      "batch": 2, "param_count": 20,
      "params": [
        {"name": "a", "shape": [2, 3], "init": ["normal", 0.02]},
        {"name": "b", "shape": [14], "init": ["ones"]}
      ],
      "batch_inputs": [
        {"name": "tokens", "shape": [2, 4], "dtype": "i32"}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        assert_eq!(m.name, "m");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![2, 3]);
        assert_eq!(m.params[0].init, Init::Normal(0.02));
        assert_eq!(m.params[1].init, Init::Ones);
        assert_eq!(m.batch_inputs[0].dtype, BatchDtype::I32);
        assert_eq!(m.tokens_per_step(), 8);
        assert!(m.step_flops() > 0.0);
    }

    #[test]
    fn init_flat_deterministic_and_respects_spec() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        let a = m.init_flat(7);
        let b = m.init_flat(7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        // "b" is all-ones
        assert!(a[6..].iter().all(|&x| x == 1.0));
        // normal part is not constant and scaled by std
        assert!(a[..6].iter().any(|&x| x != a[0]));
        assert!(a[..6].iter().all(|&x| x.abs() < 0.2));
        // different seeds differ
        assert_ne!(m.init_flat(8)[..6], a[..6]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        let bad = MINI_MANIFEST.replace("\"ones\"", "\"sevens\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn name_hash_stable() {
        assert_eq!(hash_name("embed/tok"), hash_name("embed/tok"));
        assert_ne!(hash_name("embed/tok"), hash_name("embed/pos"));
    }

    #[test]
    fn synthetic_manifest_is_consistent() {
        let m = Manifest::synthetic("synthetic-lm");
        assert_eq!(m.family, "lm");
        assert_eq!(
            m.param_count,
            m.params.iter().map(|p| p.len()).sum::<usize>()
        );
        assert_eq!(m.init_flat(3).len(), m.param_count);
        assert_eq!(m.batch_inputs.len(), 2);
        // the LM task contract: tokens + targets, batch×seq each
        assert_eq!(m.batch_inputs[0].len(), m.batch * m.seq);
        assert!(m.step_flops() > 0.0);
    }
}
