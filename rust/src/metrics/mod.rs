//! Metrics collection + CSV/JSON sinks. Every figure bench writes its
//! series through this module into `results/<experiment>/`.

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// The steps-CSV schema, in column order — the **single source of
/// truth**: [`RunMetrics::write_csv`] derives both the header and every
/// row from this slice (a row cell per entry via [`StepRow::cell`]), so
/// the two can never drift, and the column table in
/// `docs/BENCHMARKS.md` is tested against it.
pub const STEP_COLUMNS: &[&str] = &[
    "step",
    "sim_time",
    "loss",
    "inter_bytes",
    "intra_bytes",
    "compute_time",
    "exposed_comm",
    "hidden_comm",
    "comm_events",
    "staleness",
    "node_staleness",
    "rate",
    "sync_in_flight",
    "dropped_syncs",
    "peer_set",
    "membership",
    "retries",
    "corrupt_detected",
    "faulted_links",
    "wall_time",
];

/// One training-step record.
#[derive(Clone, Debug)]
pub struct StepRow {
    pub step: u64,
    /// Simulated wall-clock at the *end* of this step (s).
    pub sim_time: f64,
    /// Mean train loss across ranks.
    pub loss: f64,
    /// Inter-node bytes sent this step (whole cluster).
    pub inter_bytes: u64,
    /// Intra-node bytes this step.
    pub intra_bytes: u64,
    /// Critical rank's compute busy-time this step (s, simulated).
    pub compute_time: f64,
    /// Communication the critical rank could not hide behind compute (s).
    pub exposed_comm: f64,
    /// Communication overlapped with compute on the critical rank (s).
    pub hidden_comm: f64,
    /// Comm events the engine scheduled this step (grows with
    /// `--bucket-mb` bucketing; whole-phase schedules emit one per phase
    /// per group).
    pub comm_events: u64,
    /// The run's `--staleness` knob (steps between an async DiLoCo
    /// launch and the application of its mean; 0 = synchronous). Under
    /// per-node staleness this is the table's maximum; `node_staleness`
    /// carries the full table.
    pub staleness: u64,
    /// Resolved per-node staleness table, `;`-joined in node order
    /// (e.g. `"2;4"`); empty for runs without the async machinery.
    pub node_staleness: String,
    /// Per-node compression rates under `--compress-control aimd`,
    /// `;`-joined in node order at 4 decimals (e.g. `"0.1250;0.0312"`);
    /// empty while the controller is off — fixed-rate runs keep the
    /// column blank.
    pub rate: String,
    /// Deferred syncs in flight at the end of this step (shards whose
    /// launched gather has not arrived yet; always 0 for synchronous
    /// schemes).
    pub sync_in_flight: u64,
    /// Per-node count of peer contributions that missed this node's
    /// arrival deadline this step (`;`-joined in node order; dropped
    /// under `--late-policy drop`, carried to the next window under
    /// `partial`; always all-zero under `wait`). Empty when the
    /// straggler-tolerant path is inactive.
    pub dropped_syncs: String,
    /// Per-member peer-set sizes of the sync window launched this step
    /// (`;`-joined in group order — e.g. `"1;1;1;1"` for a random-pair
    /// matching, `"2;2;2;2"` for a ring). Empty under `--topology full`
    /// and on steps that launch no window.
    pub peer_set: String,
    /// Per-node liveness mask at the end of this step, one `1`/`0` char
    /// per node in node order (e.g. `"1011"` = node 1 down). Empty when
    /// the run has no membership timeline (`--churn`/`--crash` unused).
    pub membership: String,
    /// Retry attempts charged on the NIC this step (`--link-fault` +
    /// `--max-retries` self-healing lane; 0 on a perfect network).
    pub retries: u64,
    /// Corrupt deliveries caught by the payload checksum this step
    /// (each was retried instead of averaged into the model).
    pub corrupt_detected: u64,
    /// Directed links with at least one fault rule active at this step
    /// (`--link-fault`; wildcards expand over the mesh).
    pub faulted_links: u64,
    /// Real wall time spent computing this step (profiling only).
    pub wall_time: f64,
}

impl StepRow {
    /// The CSV cell for one [`STEP_COLUMNS`] column. The writer iterates
    /// the schema slice, so a field added here without a schema entry
    /// (or vice versa) is unreachable/panics in every test that writes a
    /// CSV — the drift shows up immediately, not in a reader.
    fn cell(&self, col: &str) -> String {
        match col {
            "step" => self.step.to_string(),
            "sim_time" => format!("{:.6}", self.sim_time),
            "loss" => format!("{:.6}", self.loss),
            "inter_bytes" => self.inter_bytes.to_string(),
            "intra_bytes" => self.intra_bytes.to_string(),
            "compute_time" => format!("{:.9}", self.compute_time),
            "exposed_comm" => format!("{:.9}", self.exposed_comm),
            "hidden_comm" => format!("{:.9}", self.hidden_comm),
            "comm_events" => self.comm_events.to_string(),
            "staleness" => self.staleness.to_string(),
            "node_staleness" => self.node_staleness.clone(),
            "rate" => self.rate.clone(),
            "sync_in_flight" => self.sync_in_flight.to_string(),
            "dropped_syncs" => self.dropped_syncs.clone(),
            "peer_set" => self.peer_set.clone(),
            "membership" => self.membership.clone(),
            "retries" => self.retries.to_string(),
            "corrupt_detected" => self.corrupt_detected.to_string(),
            "faulted_links" => self.faulted_links.to_string(),
            "wall_time" => format!("{:.6}", self.wall_time),
            other => unreachable!("column {other} is not in STEP_COLUMNS"),
        }
    }
}

/// One validation record.
#[derive(Clone, Debug)]
pub struct ValRow {
    pub step: u64,
    pub sim_time: f64,
    pub loss: f64,
}

/// A finished run's full series.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub label: String,
    pub steps: Vec<StepRow>,
    pub val: Vec<ValRow>,
}

impl RunMetrics {
    pub fn new(label: impl Into<String>) -> RunMetrics {
        RunMetrics {
            label: label.into(),
            ..Default::default()
        }
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.steps.last().map(|r| r.loss)
    }

    pub fn final_val_loss(&self) -> Option<f64> {
        self.val.last().map(|r| r.loss)
    }

    pub fn total_sim_time(&self) -> f64 {
        self.steps.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    pub fn total_inter_bytes(&self) -> u64 {
        self.steps.iter().map(|r| r.inter_bytes).sum()
    }

    /// Total communication time the critical path could not hide (s).
    pub fn total_exposed_comm(&self) -> f64 {
        self.steps.iter().map(|r| r.exposed_comm).sum()
    }

    /// Total communication time overlapped behind compute (s).
    pub fn total_hidden_comm(&self) -> f64 {
        self.steps.iter().map(|r| r.hidden_comm).sum()
    }

    /// Fraction of the run's communication that was hidden by overlap.
    pub fn overlap_efficiency(&self) -> f64 {
        let hidden = self.total_hidden_comm();
        let total = hidden + self.total_exposed_comm();
        if total <= 0.0 {
            0.0
        } else {
            hidden / total
        }
    }

    /// Total late peer contributions across the run (the sum over steps
    /// and nodes of the `dropped_syncs` column; 0 when the straggler-
    /// tolerant path never fired).
    pub fn total_dropped_syncs(&self) -> u64 {
        self.steps
            .iter()
            .map(|r| {
                r.dropped_syncs
                    .split(';')
                    .filter_map(|s| s.parse::<u64>().ok())
                    .sum::<u64>()
            })
            .sum()
    }

    /// Total retry attempts across the run (the `retries` column; 0
    /// without `--link-fault`).
    pub fn total_retries(&self) -> u64 {
        self.steps.iter().map(|r| r.retries).sum()
    }

    /// Total checksum-caught corrupt deliveries across the run (the
    /// `corrupt_detected` column).
    pub fn total_corrupt_detected(&self) -> u64 {
        self.steps.iter().map(|r| r.corrupt_detected).sum()
    }

    /// Mean simulated time per step.
    pub fn mean_step_time(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.total_sim_time() / self.steps.len() as f64
    }

    /// Mean loss over the last `n` steps (smoother end-of-run comparison).
    pub fn tail_loss(&self, n: usize) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64)
    }

    pub fn write_csv(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let safe = self.label.replace('/', "-");
        let mut f = std::fs::File::create(dir.join(format!("{safe}.steps.csv")))?;
        writeln!(f, "{}", STEP_COLUMNS.join(","))?;
        for r in &self.steps {
            let cells: Vec<String> = STEP_COLUMNS.iter().map(|c| r.cell(c)).collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        if !self.val.is_empty() {
            let mut f = std::fs::File::create(dir.join(format!("{safe}.val.csv")))?;
            writeln!(f, "step,sim_time,loss")?;
            for r in &self.val {
                writeln!(f, "{},{:.6},{:.6}", r.step, r.sim_time, r.loss)?;
            }
        }
        Ok(())
    }

    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("steps", Json::Num(self.steps.len() as f64)),
            (
                "final_loss",
                self.final_loss().map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "final_val_loss",
                self.final_val_loss().map(Json::Num).unwrap_or(Json::Null),
            ),
            ("sim_time_s", Json::Num(self.total_sim_time())),
            ("mean_step_time_s", Json::Num(self.mean_step_time())),
            (
                "inter_bytes_total",
                Json::Num(self.total_inter_bytes() as f64),
            ),
            ("exposed_comm_s", Json::Num(self.total_exposed_comm())),
            ("hidden_comm_s", Json::Num(self.total_hidden_comm())),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency())),
        ])
    }
}

/// ASCII sparkline of a loss series (bench output readability).
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let width = width.max(1).min(values.len());
    let mut out = String::with_capacity(width * 3);
    for w in 0..width {
        // Evenly sample, always including the first and last values.
        let i = if width == 1 {
            0
        } else {
            (w as f64 * (values.len() - 1) as f64 / (width - 1) as f64).round() as usize
        };
        let v = values[i];
        let idx = (((v - lo) / span) * 7.0).round() as usize;
        out.push(BARS[idx.min(7)]);
    }
    out
}

/// Group several runs into one comparison table (one row per run).
pub fn comparison_table(runs: &[&RunMetrics]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>10} {:>10} {:>12} {:>14} {:>12} {:>12} {:>8}\n",
        "run", "loss", "val_loss", "sim_time", "inter_bytes", "t/step", "exposed", "hidden%"
    ));
    for r in runs {
        out.push_str(&format!(
            "{:<28} {:>10} {:>10} {:>12} {:>14} {:>12} {:>12} {:>7.0}%\n",
            r.label,
            r.final_loss()
                .map(|l| format!("{l:.4}"))
                .unwrap_or_else(|| "-".into()),
            r.final_val_loss()
                .map(|l| format!("{l:.4}"))
                .unwrap_or_else(|| "-".into()),
            crate::util::fmt_secs(r.total_sim_time()),
            crate::util::fmt_bytes(r.total_inter_bytes()),
            crate::util::fmt_secs(r.mean_step_time()),
            crate::util::fmt_secs(r.total_exposed_comm()),
            r.overlap_efficiency() * 100.0,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(label: &str, n: u64) -> RunMetrics {
        let mut m = RunMetrics::new(label);
        for s in 0..n {
            m.steps.push(StepRow {
                step: s,
                sim_time: (s + 1) as f64 * 0.5,
                loss: 5.0 - s as f64 * 0.1,
                inter_bytes: 100,
                intra_bytes: 200,
                compute_time: 0.3,
                exposed_comm: 0.15,
                hidden_comm: 0.05,
                comm_events: 6,
                staleness: 0,
                node_staleness: "0;0".into(),
                rate: if s % 2 == 0 { "0.1250;0.0625".into() } else { String::new() },
                sync_in_flight: 0,
                dropped_syncs: if s % 2 == 0 { "1;0".into() } else { String::new() },
                peer_set: if s % 2 == 0 { "1;1".into() } else { String::new() },
                membership: if s % 2 == 0 { "10".into() } else { String::new() },
                retries: if s % 3 == 0 { 2 } else { 0 },
                corrupt_detected: if s % 5 == 0 { 1 } else { 0 },
                faulted_links: 1,
                wall_time: 0.01,
            });
        }
        m.val.push(ValRow {
            step: n,
            sim_time: n as f64 * 0.5,
            loss: 4.2,
        });
        m
    }

    #[test]
    fn aggregates() {
        let m = mk("x", 10);
        assert_eq!(m.final_loss(), Some(5.0 - 0.9));
        assert_eq!(m.final_val_loss(), Some(4.2));
        assert_eq!(m.total_inter_bytes(), 1000);
        // per-node dropped column sums across steps and nodes (empty
        // cells — inactive straggler path — count as zero)
        assert_eq!(m.total_dropped_syncs(), 5);
        // fault columns aggregate the same way
        assert_eq!(m.total_retries(), 8);
        assert_eq!(m.total_corrupt_detected(), 2);
        assert!((m.total_sim_time() - 5.0).abs() < 1e-9);
        assert!((m.mean_step_time() - 0.5).abs() < 1e-9);
        let t = m.tail_loss(3).unwrap();
        assert!((t - (4.3 + 4.2 + 4.1) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn csv_written_and_parseable() {
        let dir = std::env::temp_dir().join("detonation-metrics-test");
        let m = mk("a/b", 5);
        m.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("a-b.steps.csv")).unwrap();
        assert!(text.starts_with("step,"));
        assert!(text.lines().next().unwrap().contains("exposed_comm,hidden_comm"));
        assert!(text
            .lines()
            .next()
            .unwrap()
            .contains("retries,corrupt_detected,faulted_links"));
        assert_eq!(text.lines().count(), 6);
        // every data row carries the full column set
        let cols = text.lines().next().unwrap().split(',').count();
        for line in text.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
        let val = std::fs::read_to_string(dir.join("a-b.val.csv")).unwrap();
        assert_eq!(val.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn step_columns_schema_covers_every_cell() {
        // Every schema column formats (the unreachable arm would panic
        // here on drift), the header is exactly the schema, and the
        // `rate` column sits where the docs say it does.
        let m = mk("schema", 2);
        for r in &m.steps {
            for c in STEP_COLUMNS {
                let _ = r.cell(c);
            }
        }
        assert_eq!(STEP_COLUMNS.len(), 20);
        assert_eq!(
            STEP_COLUMNS.iter().position(|&c| c == "rate"),
            Some(STEP_COLUMNS.iter().position(|&c| c == "node_staleness").unwrap() + 1)
        );
    }

    #[test]
    fn docs_column_table_matches_schema() {
        // docs/BENCHMARKS.md documents the steps CSV as a markdown table
        // whose first cell is the backticked column name; the table must
        // list exactly STEP_COLUMNS, in order.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../docs/BENCHMARKS.md");
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let mut cols: Vec<String> = Vec::new();
        let mut in_section = false;
        for line in text.lines() {
            if line.starts_with('#') {
                in_section = line.to_lowercase().contains("steps csv");
                continue;
            }
            if !in_section {
                continue;
            }
            if let Some(rest) = line.trim_start().strip_prefix("| `") {
                if let Some((name, _)) = rest.split_once('`') {
                    cols.push(name.to_string());
                }
            }
        }
        assert_eq!(
            cols,
            STEP_COLUMNS.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            "docs/BENCHMARKS.md steps-CSV column table is out of sync with STEP_COLUMNS"
        );
    }

    #[test]
    fn comm_breakdown_aggregates() {
        let m = mk("x", 10);
        assert!((m.total_exposed_comm() - 1.5).abs() < 1e-9);
        assert!((m.total_hidden_comm() - 0.5).abs() < 1e-9);
        assert!((m.overlap_efficiency() - 0.25).abs() < 1e-9);
        assert!(m.summary_json().get("overlap_efficiency").is_some());
        // empty run: defined, not NaN
        assert_eq!(RunMetrics::new("e").overlap_efficiency(), 0.0);
    }

    #[test]
    fn sparkline_monotone_series() {
        let vals: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let s = sparkline(&vals, 10);
        assert_eq!(s.chars().count(), 10);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
        assert_eq!(sparkline(&[], 10), "");
    }

    #[test]
    fn table_contains_all_runs() {
        let a = mk("run-a", 3);
        let b = mk("run-b", 3);
        let t = comparison_table(&[&a, &b]);
        assert!(t.contains("run-a") && t.contains("run-b"));
    }
}
