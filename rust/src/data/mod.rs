//! Synthetic datasets standing in for the paper's corpora (DESIGN.md §2):
//!
//! * [`LmTask`]   — Zipf/Markov token stream           (Dolma v1.6 stand-in)
//! * [`TranslationTask`] — deterministic synthetic language pair
//!   (token remap + reversal + offset)                 (Opus Books En↔Fr)
//! * [`ImageTask`] — procedural texture/shape classes  (Cifar100)
//!
//! All three are generated on the fly from a seed: the *learning problem*
//! is real (non-trivial structure a transformer must fit, with held-out
//! validation splits), while requiring no downloads. Data-parallel
//! divergence — the phenomenon decoupled training controls — comes from
//! giving every (node, accel) stream a distinct RNG split, exactly like
//! per-rank dataset sharding in the paper's setup.

use crate::runtime::{BatchData, BatchDtype, Manifest};
use crate::util::rng::Rng;

/// A task generates per-rank training batches and a fixed validation set.
/// `Sync` because the trainer fans per-stream batch generation out to
/// `std::thread::scope` workers (generators are stateless given args).
pub trait Task: Send + Sync {
    /// Batch for `(rank_stream, step)`; deterministic in its arguments.
    fn train_batch(&self, stream: u64, step: u64) -> Vec<BatchData>;
    /// The `i`-th validation batch (held-out split; same for all ranks).
    fn val_batch(&self, i: u64) -> Vec<BatchData>;
    fn name(&self) -> &'static str;
}

/// Build the right task for a model manifest.
pub fn task_for(manifest: &Manifest, seed: u64) -> Box<dyn Task> {
    match manifest.family.as_str() {
        "lm" => Box::new(LmTask::new(manifest, seed)),
        "seq2seq" => Box::new(TranslationTask::new(manifest, seed)),
        "vit" => Box::new(ImageTask::new(manifest, seed)),
        other => panic!("unknown model family {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Causal LM: Zipf-weighted Markov chain over the vocabulary
// ---------------------------------------------------------------------------

/// Markov text: each token has `FANOUT` likely successors (chosen once per
/// seed); transitions pick among them Zipf-style with occasional jumps.
/// Entropy is tunable and well below uniform — a model that learns the
/// chain beats the ln(V) baseline, giving real loss curves.
pub struct LmTask {
    vocab: usize,
    batch: usize,
    seq: usize,
    seed: u64,
    successors: Vec<u32>, // vocab × FANOUT
}

const FANOUT: usize = 4;
const JUMP_P: f64 = 0.1;

impl LmTask {
    pub fn new(m: &Manifest, seed: u64) -> LmTask {
        assert_eq!(m.family, "lm");
        let mut rng = Rng::new(seed ^ 0x11_22);
        let mut successors = Vec::with_capacity(m.vocab * FANOUT);
        for _ in 0..m.vocab {
            for _ in 0..FANOUT {
                successors.push(rng.below(m.vocab as u64) as u32);
            }
        }
        LmTask {
            vocab: m.vocab,
            batch: m.batch,
            seq: m.seq,
            seed,
            successors,
        }
    }

    fn gen(&self, rng: &mut Rng) -> Vec<BatchData> {
        // Generate seq+1 tokens; inputs = [0..seq), targets = [1..seq+1).
        let n = self.batch * (self.seq + 1);
        let mut toks = Vec::with_capacity(n);
        for _ in 0..self.batch {
            let mut t = rng.below(self.vocab as u64) as u32;
            toks.push(t as i32);
            for _ in 0..self.seq {
                t = if rng.next_f64() < JUMP_P {
                    rng.below(self.vocab as u64) as u32
                } else {
                    let succ = rng.zipf(FANOUT, 1.3);
                    self.successors[t as usize * FANOUT + succ]
                };
                toks.push(t as i32);
            }
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let row = &toks[b * (self.seq + 1)..(b + 1) * (self.seq + 1)];
            tokens.extend_from_slice(&row[..self.seq]);
            targets.extend_from_slice(&row[1..]);
        }
        vec![BatchData::I32(tokens), BatchData::I32(targets)]
    }
}

impl Task for LmTask {
    fn train_batch(&self, stream: u64, step: u64) -> Vec<BatchData> {
        let mut rng = Rng::new(self.seed ^ 0xA5A5)
            .split(stream)
            .split(step ^ 0x51ED);
        self.gen(&mut rng)
    }

    fn val_batch(&self, i: u64) -> Vec<BatchData> {
        // Held-out split: a stream tag no training rank ever uses.
        let mut rng = Rng::new(self.seed ^ 0xA5A5).split(u64::MAX).split(i);
        self.gen(&mut rng)
    }

    fn name(&self) -> &'static str {
        "lm-markov-zipf"
    }
}

// ---------------------------------------------------------------------------
// Seq2seq: synthetic language pair
// ---------------------------------------------------------------------------

/// "Translation": the target sentence is the source with (1) every token
/// remapped through a fixed random bijection, (2) local 2-blocks swapped
/// (a deterministic reordering), teacher-forced with BOS=0. The model
/// must learn a token table plus a positional transformation — the same
/// kind of structure (lexical + reordering) real translation exercises.
pub struct TranslationTask {
    vocab: usize,
    batch: usize,
    src_seq: usize,
    tgt_seq: usize,
    seed: u64,
    mapping: Vec<u32>,
}

impl TranslationTask {
    pub fn new(m: &Manifest, seed: u64) -> TranslationTask {
        assert_eq!(m.family, "seq2seq");
        // Random bijection over [2, vocab): 0 = BOS, 1 = reserved.
        let mut ids: Vec<u32> = (2..m.vocab as u32).collect();
        Rng::new(seed ^ 0x77_33).shuffle(&mut ids);
        let mut mapping = vec![0u32; m.vocab];
        for (i, &v) in ids.iter().enumerate() {
            mapping[i + 2] = v;
        }
        TranslationTask {
            vocab: m.vocab,
            batch: m.batch,
            src_seq: m.src_seq,
            tgt_seq: m.seq,
            seed,
            mapping,
        }
    }

    fn gen(&self, rng: &mut Rng) -> Vec<BatchData> {
        let mut src = Vec::with_capacity(self.batch * self.src_seq);
        let mut tgt_in = Vec::with_capacity(self.batch * self.tgt_seq);
        let mut tgt_out = Vec::with_capacity(self.batch * self.tgt_seq);
        for _ in 0..self.batch {
            // Zipf source tokens (natural-language-like frequencies).
            let s: Vec<u32> = (0..self.src_seq)
                .map(|_| 2 + rng.zipf(self.vocab - 2, 1.1) as u32)
                .collect();
            // Translate: remap + swap adjacent pairs.
            let mut t: Vec<u32> = s.iter().map(|&x| self.mapping[x as usize]).collect();
            for i in (0..t.len() - 1).step_by(2) {
                t.swap(i, i + 1);
            }
            t.truncate(self.tgt_seq);
            while t.len() < self.tgt_seq {
                t.push(1); // pad with reserved token
            }
            src.extend(s.iter().map(|&x| x as i32));
            tgt_in.push(0); // BOS
            tgt_in.extend(t[..self.tgt_seq - 1].iter().map(|&x| x as i32));
            tgt_out.extend(t.iter().map(|&x| x as i32));
        }
        vec![
            BatchData::I32(src),
            BatchData::I32(tgt_in),
            BatchData::I32(tgt_out),
        ]
    }
}

impl Task for TranslationTask {
    fn train_batch(&self, stream: u64, step: u64) -> Vec<BatchData> {
        let mut rng = Rng::new(self.seed ^ 0xBEEF)
            .split(stream)
            .split(step ^ 0x7A11);
        self.gen(&mut rng)
    }

    fn val_batch(&self, i: u64) -> Vec<BatchData> {
        let mut rng = Rng::new(self.seed ^ 0xBEEF).split(u64::MAX).split(i);
        self.gen(&mut rng)
    }

    fn name(&self) -> &'static str {
        "seq2seq-synthetic-pair"
    }
}

// ---------------------------------------------------------------------------
// Vision: procedural texture classes
// ---------------------------------------------------------------------------

/// Each class is a 2-D sinusoid pattern with class-specific frequency and
/// phase; images are the pattern over the patch grid plus noise. Patches
/// arrive pre-extracted (B, P, patch_dim) — patchification is data prep,
/// not model compute, exactly as ViT treats it.
pub struct ImageTask {
    classes: usize,
    batch: usize,
    patches: usize,
    patch_dim: usize,
    seed: u64,
    /// Per-class (fx, fy, phase, amp) pattern parameters.
    class_params: Vec<(f32, f32, f32, f32)>,
}

impl ImageTask {
    pub fn new(m: &Manifest, seed: u64) -> ImageTask {
        assert_eq!(m.family, "vit");
        let mut rng = Rng::new(seed ^ 0x99_44);
        let class_params = (0..m.vocab)
            .map(|_| {
                (
                    0.3 + 2.2 * rng.next_f32(),
                    0.3 + 2.2 * rng.next_f32(),
                    std::f32::consts::TAU * rng.next_f32(),
                    0.6 + 0.6 * rng.next_f32(),
                )
            })
            .collect();
        ImageTask {
            classes: m.vocab,
            batch: m.batch,
            patches: m.seq,
            patch_dim: m.patch_dim,
            seed,
            class_params,
        }
    }

    fn gen(&self, rng: &mut Rng) -> Vec<BatchData> {
        let grid = (self.patches as f64).sqrt().round() as usize;
        let pside = ((self.patch_dim / 3) as f64).sqrt().round().max(1.0) as usize;
        let mut patches = Vec::with_capacity(self.batch * self.patches * self.patch_dim);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let cls = rng.below(self.classes as u64) as usize;
            labels.push(cls as i32);
            let (fx, fy, phase, amp) = self.class_params[cls];
            let jitter = rng.normal_f32(0.3);
            for p in 0..self.patches {
                let (py, px) = (p / grid.max(1), p % grid.max(1));
                for d in 0..self.patch_dim {
                    let ch = d % 3;
                    let within = d / 3;
                    let (wy, wx) = (within / pside.max(1), within % pside.max(1));
                    let y = (py * pside + wy) as f32;
                    let x = (px * pside + wx) as f32;
                    let v = amp
                        * (fx * x * 0.25 + fy * y * 0.25 + phase + jitter
                            + 0.5 * ch as f32)
                            .sin();
                    patches.push(v + rng.normal_f32(0.15));
                }
            }
        }
        vec![BatchData::F32(patches), BatchData::I32(labels)]
    }
}

impl Task for ImageTask {
    fn train_batch(&self, stream: u64, step: u64) -> Vec<BatchData> {
        let mut rng = Rng::new(self.seed ^ 0xCAFE)
            .split(stream)
            .split(step ^ 0x1017);
        self.gen(&mut rng)
    }

    fn val_batch(&self, i: u64) -> Vec<BatchData> {
        let mut rng = Rng::new(self.seed ^ 0xCAFE).split(u64::MAX).split(i);
        self.gen(&mut rng)
    }

    fn name(&self) -> &'static str {
        "vit-procedural-textures"
    }
}

// ---------------------------------------------------------------------------

/// Validate a batch against the manifest's input spec (failure injection
/// tests use this to assert the runtime rejects malformed data).
pub fn check_batch(manifest: &Manifest, batch: &[BatchData]) -> anyhow::Result<()> {
    anyhow::ensure!(
        batch.len() == manifest.batch_inputs.len(),
        "batch arity {} != {}",
        batch.len(),
        manifest.batch_inputs.len()
    );
    for (spec, data) in manifest.batch_inputs.iter().zip(batch) {
        anyhow::ensure!(
            data.len() == spec.len(),
            "{}: len {} != {}",
            spec.name,
            data.len(),
            spec.len()
        );
        match (spec.dtype, data) {
            (BatchDtype::I32, BatchData::I32(_)) | (BatchDtype::F32, BatchData::F32(_)) => {}
            _ => anyhow::bail!("{}: dtype mismatch", spec.name),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn lm_manifest() -> Manifest {
        Manifest::parse(
            r#"{"name":"t","family":"lm","vocab":64,"d_model":8,"n_heads":2,
            "n_layers":1,"d_ff":16,"seq":16,"src_seq":0,"patch_dim":0,
            "batch":4,"param_count":0,"params":[],
            "batch_inputs":[{"name":"tokens","shape":[4,16],"dtype":"i32"},
                            {"name":"targets","shape":[4,16],"dtype":"i32"}]}"#,
        )
        .unwrap()
    }

    fn s2s_manifest() -> Manifest {
        Manifest::parse(
            r#"{"name":"t","family":"seq2seq","vocab":64,"d_model":8,"n_heads":2,
            "n_layers":1,"d_ff":16,"seq":12,"src_seq":12,"patch_dim":0,
            "batch":4,"param_count":0,"params":[],
            "batch_inputs":[{"name":"src","shape":[4,12],"dtype":"i32"},
                            {"name":"tgt_in","shape":[4,12],"dtype":"i32"},
                            {"name":"tgt_out","shape":[4,12],"dtype":"i32"}]}"#,
        )
        .unwrap()
    }

    fn vit_manifest() -> Manifest {
        Manifest::parse(
            r#"{"name":"t","family":"vit","vocab":8,"d_model":8,"n_heads":2,
            "n_layers":1,"d_ff":16,"seq":16,"src_seq":0,"patch_dim":12,
            "batch":4,"param_count":0,"params":[],
            "batch_inputs":[{"name":"patches","shape":[4,16,12],"dtype":"f32"},
                            {"name":"labels","shape":[4],"dtype":"i32"}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn all_tasks_match_their_manifests() {
        for (m, _) in [
            (lm_manifest(), "lm"),
            (s2s_manifest(), "s2s"),
            (vit_manifest(), "vit"),
        ] {
            let task = task_for(&m, 1);
            check_batch(&m, &task.train_batch(0, 0)).unwrap();
            check_batch(&m, &task.val_batch(0)).unwrap();
        }
    }

    #[test]
    fn batches_deterministic_per_stream_and_step() {
        let m = lm_manifest();
        let t = LmTask::new(&m, 5);
        let a = t.train_batch(3, 10);
        let b = t.train_batch(3, 10);
        match (&a[0], &b[0]) {
            (BatchData::I32(x), BatchData::I32(y)) => assert_eq!(x, y),
            _ => panic!(),
        }
        // different streams / steps differ
        let c = t.train_batch(4, 10);
        let d = t.train_batch(3, 11);
        match (&a[0], &c[0], &d[0]) {
            (BatchData::I32(x), BatchData::I32(y), BatchData::I32(z)) => {
                assert_ne!(x, y);
                assert_ne!(x, z);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lm_targets_are_shifted_inputs() {
        let m = lm_manifest();
        let t = LmTask::new(&m, 7);
        let batch = t.train_batch(0, 0);
        let (tokens, targets) = match (&batch[0], &batch[1]) {
            (BatchData::I32(a), BatchData::I32(b)) => (a, b),
            _ => panic!(),
        };
        // within each row, targets[i] == tokens[i+1]
        for b in 0..4 {
            let row_t = &tokens[b * 16..(b + 1) * 16];
            let row_y = &targets[b * 16..(b + 1) * 16];
            assert_eq!(&row_t[1..], &row_y[..15], "row {b}");
        }
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let m = lm_manifest();
        let t = LmTask::new(&m, 9);
        for step in 0..5 {
            for data in t.train_batch(1, step) {
                if let BatchData::I32(v) = data {
                    assert!(v.iter().all(|&x| (0..64).contains(&x)));
                }
            }
        }
    }

    #[test]
    fn translation_is_learnable_function_of_source() {
        // Same source (same rng) → same target; mapping is a bijection.
        let m = s2s_manifest();
        let t = TranslationTask::new(&m, 11);
        let mut seen = std::collections::HashSet::new();
        for (i, &v) in t.mapping.iter().enumerate().skip(2) {
            assert!(v >= 2 && (v as usize) < 64, "mapping[{i}]={v}");
            assert!(seen.insert(v), "mapping not injective at {i}");
        }
        let b = t.train_batch(0, 0);
        let (src, tgt_in, tgt_out) = match (&b[0], &b[1], &b[2]) {
            (BatchData::I32(a), BatchData::I32(b_), BatchData::I32(c)) => (a, b_, c),
            _ => panic!(),
        };
        // teacher forcing: tgt_in is BOS + tgt_out shifted
        for r in 0..4 {
            assert_eq!(tgt_in[r * 12], 0);
            assert_eq!(&tgt_in[r * 12 + 1..(r + 1) * 12], &tgt_out[r * 12..(r + 1) * 12 - 1]);
        }
        // target tokens = swapped remap of source
        for r in 0..4 {
            let s = &src[r * 12..(r + 1) * 12];
            let y = &tgt_out[r * 12..(r + 1) * 12];
            // position 0 holds remap of s[1] (pair swap)
            assert_eq!(y[0], t.mapping[s[1] as usize] as i32);
            assert_eq!(y[1], t.mapping[s[0] as usize] as i32);
        }
    }

    #[test]
    fn image_classes_are_separable() {
        // Mean patch energy must differ across classes more than within —
        // a crude separability check that the task is learnable.
        let m = vit_manifest();
        let t = ImageTask::new(&m, 13);
        let mut per_class_means: Vec<Vec<f32>> = vec![Vec::new(); 8];
        for step in 0..40 {
            let b = t.train_batch(0, step);
            let (patches, labels) = match (&b[0], &b[1]) {
                (BatchData::F32(p), BatchData::I32(l)) => (p, l),
                _ => panic!(),
            };
            let per_img = 16 * 12;
            for (i, &l) in labels.iter().enumerate() {
                let img = &patches[i * per_img..(i + 1) * per_img];
                let mean: f32 = img.iter().map(|x| x.abs()).sum::<f32>() / per_img as f32;
                per_class_means[l as usize].push(mean);
            }
        }
        let filled: Vec<f32> = per_class_means
            .iter()
            .filter(|v| !v.is_empty())
            .map(|v| v.iter().sum::<f32>() / v.len() as f32)
            .collect();
        assert!(filled.len() >= 4, "sampled too few classes");
        let spread = filled
            .iter()
            .cloned()
            .fold(f32::MIN, f32::max)
            - filled.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 0.01, "classes indistinguishable: {filled:?}");
    }

    #[test]
    fn val_differs_from_train() {
        let m = lm_manifest();
        let t = LmTask::new(&m, 15);
        let tr = t.train_batch(0, 0);
        let va = t.val_batch(0);
        match (&tr[0], &va[0]) {
            (BatchData::I32(a), BatchData::I32(b)) => assert_ne!(a, b),
            _ => panic!(),
        }
    }

    #[test]
    fn check_batch_rejects_malformed() {
        let m = lm_manifest();
        // wrong arity
        assert!(check_batch(&m, &[BatchData::I32(vec![0; 64])]).is_err());
        // wrong length
        assert!(check_batch(
            &m,
            &[BatchData::I32(vec![0; 63]), BatchData::I32(vec![0; 64])]
        )
        .is_err());
        // wrong dtype
        assert!(check_batch(
            &m,
            &[BatchData::F32(vec![0.0; 64]), BatchData::I32(vec![0; 64])]
        )
        .is_err());
    }
}
