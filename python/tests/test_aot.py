"""AOT pipeline tests: HLO text emission + manifest integrity.

These guard the Python→Rust interchange: if the HLO text or the manifest
schema drifts, the Rust runtime tests will fail too — this catches it at
build time.
"""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import dct_topk


def test_hlo_text_emits_and_is_parseable_header():
    cfg = model.CONFIGS["lm-tiny"]
    hlo = aot.to_hlo_text(
        jax.jit(model.make_train_step(cfg)).lower(*model.example_args(cfg)))
    assert hlo.startswith("HloModule"), hlo[:64]
    assert "ENTRY" in hlo
    # 64-bit-id regression guard: text form never contains id= attributes
    # that overflow INT_MAX when reparsed — spot-check we kept text format.
    assert not hlo.startswith("\x08"), "binary proto emitted instead of text"


def test_emit_model_writes_all_files():
    cfg = model.CONFIGS["lm-tiny"]
    with tempfile.TemporaryDirectory() as d:
        aot.emit_model(cfg, d)
        for suffix in ["train.hlo.txt", "eval.hlo.txt", "meta.json"]:
            path = os.path.join(d, f"{cfg.name}.{suffix}")
            assert os.path.exists(path), suffix
            assert os.path.getsize(path) > 0
        meta = json.load(open(os.path.join(d, f"{cfg.name}.meta.json")))
        assert meta["name"] == cfg.name
        assert meta["param_count"] == model.param_count(cfg)
        assert [p["name"] for p in meta["params"]] == model.param_order(cfg)
        spec = model.init_spec(cfg)
        for p in meta["params"]:
            assert tuple(p["shape"]) == spec[p["name"]][0]
            assert p["init"][0] in ("normal", "zeros", "ones")


def test_emit_extract_roundtrips_numerically():
    """The extraction artifact computes the same q/m_next as calling the
    kernel directly (the artifact is just its lowered form)."""
    with tempfile.TemporaryDirectory() as d:
        aot.emit_extract(1024, 32, 4, True, d)
        path = os.path.join(d, "dct_extract_1024_c32_k4_sign.hlo.txt")
        assert os.path.exists(path)
        hlo = open(path).read()
        assert hlo.startswith("HloModule")
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(size=1024).astype(np.float32))
    q, m_next = dct_topk.extract_fast_components(m, 32, 4, True)
    assert q.shape == (1024,) and m_next.shape == (1024,)


def test_manifest_batch_inputs_schema():
    for name in ["lm-tiny", "seq2seq-tiny", "vit-tiny"]:
        cfg = model.CONFIGS[name]
        for bname, shape, dt in model.batch_spec(cfg):
            assert dt in ("i32", "f32")
            assert all(s > 0 for s in shape)
            assert shape[0] == cfg.batch, (name, bname)


def test_default_models_all_known():
    for name in aot.DEFAULT_MODELS:
        assert name in model.CONFIGS


@pytest.mark.parametrize("family,names", [
    ("lm", ["lm-tiny", "lm-small", "lm-100m"]),
    ("seq2seq", ["seq2seq-tiny", "seq2seq-small"]),
    ("vit", ["vit-tiny", "vit-small"]),
])
def test_config_registry_families(family, names):
    for n in names:
        assert model.CONFIGS[n].family == family
