"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes/chunk/k/sign and asserts allclose between the Pallas
kernels (interpret=True, the exact code AOT-lowered into the artifacts)
and the ref.py oracles.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn
from compile.kernels import dct_topk, ref

jax.config.update("jax_enable_x64", False)


def randn(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# DCT basis identities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128, 256])
def test_dct_basis_orthonormal(n):
    b = np.asarray(ref.dct_basis(n))
    np.testing.assert_allclose(b @ b.T, np.eye(n), atol=2e-5)


def test_dct_basis_pinned_values():
    """Pin a few entries to guard the normalization convention (the same
    constants are pinned in rust/src/dct tests — drift on either side is a
    cross-language mismatch)."""
    b = np.asarray(ref.dct_basis(4))
    assert abs(b[0, 0] - 0.5) < 1e-6                       # sqrt(1/4)
    assert abs(b[1, 0] - math.sqrt(0.5) * math.cos(math.pi / 8)) < 1e-6
    assert abs(b[3, 3] - math.sqrt(0.5) * math.cos(7 * 3 * math.pi / 8)) < 1e-6


def test_dct_constant_signal_concentrates_in_dc():
    x = jnp.ones(64)
    c = ref.dct2_ref(x, ref.dct_basis(64))
    assert abs(float(c[0]) - 8.0) < 1e-4      # sqrt(64) * 1
    assert float(jnp.max(jnp.abs(c[1:]))) < 1e-4


# ---------------------------------------------------------------------------
# Pallas chunked DCT vs oracle  (hypothesis sweep: shapes / chunks / blocks)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_chunks=st.integers(min_value=1, max_value=300),
    chunk_pow=st.integers(min_value=2, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_dct2_matches_ref(n_chunks, chunk_pow, seed):
    chunk = 2 ** chunk_pow
    rng = np.random.default_rng(seed)
    x = randn(rng, (n_chunks * chunk,))
    got = dct_topk.chunked_dct2(x, chunk)
    want = ref.chunked_dct2_ref(x, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n_chunks=st.integers(min_value=1, max_value=300),
    chunk_pow=st.integers(min_value=2, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_dct_roundtrip(n_chunks, chunk_pow, seed):
    chunk = 2 ** chunk_pow
    rng = np.random.default_rng(seed)
    x = randn(rng, (n_chunks * chunk,))
    c = dct_topk.chunked_dct2(x, chunk)
    back = dct_topk.chunked_dct3(c, chunk)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block", [8, 64, 128, 256])
def test_pallas_dct_block_size_invariance(block):
    """The BlockSpec tiling must not change the math."""
    rng = np.random.default_rng(7)
    x = randn(rng, (4096,))
    base = ref.chunked_dct2_ref(x, 64)
    got = dct_topk.chunked_dct2(x, 64, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Extraction (DCT + topk + sign) vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n_chunks=st.integers(min_value=1, max_value=64),
    chunk_pow=st.integers(min_value=3, max_value=7),
    k_pow=st.integers(min_value=0, max_value=5),
    sign=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_extract_matches_ref(n_chunks, chunk_pow, k_pow, sign, seed):
    chunk = 2 ** chunk_pow
    k = min(2 ** k_pow, chunk)
    rng = np.random.default_rng(seed)
    m = randn(rng, (n_chunks * chunk,))
    q, m_next = dct_topk.extract_fast_components(m, chunk, k, sign)
    q_ref, m_ref, _ = ref.extract_fast_components_ref(m, chunk, k, sign)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_next), np.asarray(m_ref),
                               rtol=1e-4, atol=1e-4)


def test_extract_residual_energy_decreases():
    """Removing the top-k components must strictly shrink momentum energy."""
    rng = np.random.default_rng(3)
    m = randn(rng, (64 * 32,))
    _, m_next = dct_topk.extract_fast_components(m, 32, 4, True)
    assert float(jnp.sum(m_next**2)) < float(jnp.sum(m**2))


def test_extract_k_full_removes_everything():
    """k == chunk keeps all coefficients → residual is ~0."""
    rng = np.random.default_rng(4)
    m = randn(rng, (16 * 32,))
    _, m_next = dct_topk.extract_fast_components(m, 32, 32, False)
    np.testing.assert_allclose(np.asarray(m_next), 0.0, atol=1e-4)


def test_extract_transmit_is_ternary_decode_when_signed():
    """With sign=True the transmitted coefficients are in {-1,0,1}: check by
    re-encoding q and verifying every nonzero coefficient is ±1."""
    rng = np.random.default_rng(5)
    m = randn(rng, (8 * 64,))
    q, _ = dct_topk.extract_fast_components(m, 64, 8, True)
    c = np.asarray(ref.chunked_dct2_ref(q, 64))
    nz = c[np.abs(c) > 1e-4]
    np.testing.assert_allclose(np.abs(nz), 1.0, atol=1e-4)
    assert (np.abs(c) > 1e-4).sum() == 8 * 8  # exactly k per chunk


# ---------------------------------------------------------------------------
# Pallas attention vs oracle (fwd + custom-VJP bwd)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=3),
    h=st.integers(min_value=1, max_value=4),
    s=st.integers(min_value=1, max_value=48),
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_attention_fwd_matches_ref(b, h, s, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (randn(rng, (b, h, s, d)) for _ in range(3))
    got = attn.attention(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_pallas_attention_cross_shape():
    """Cross-attention S != T (decoder querying encoder)."""
    rng = np.random.default_rng(11)
    q = randn(rng, (2, 4, 24, 16))
    k = randn(rng, (2, 4, 40, 16))
    v = randn(rng, (2, 4, 40, 16))
    got = attn.attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_pallas_attention_causal_requires_square():
    rng = np.random.default_rng(12)
    with pytest.raises(ValueError):
        attn.attention(randn(rng, (1, 1, 8, 4)), randn(rng, (1, 1, 9, 4)),
                       randn(rng, (1, 1, 9, 4)), causal=True)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=2, max_value=24),
    d=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_attention_bwd_matches_ref(s, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (randn(rng, (1, 2, s, d)) for _ in range(3))

    def f_pallas(q, k, v):
        return jnp.sum(jnp.tanh(attn.attention(q, k, v, causal=causal)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.tanh(ref.attention_ref(q, k, v, causal=causal)))

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-4)


def test_attention_causal_ignores_future():
    """Perturbing future keys/values must not change earlier outputs."""
    rng = np.random.default_rng(13)
    q, k, v = (randn(rng, (1, 1, 16, 8)) for _ in range(3))
    base = np.asarray(attn.attention(q, k, v, causal=True))
    k2 = k.at[0, 0, 10:].set(99.0)
    v2 = v.at[0, 0, 10:].set(-99.0)
    pert = np.asarray(attn.attention(q, k2, v2, causal=True))
    np.testing.assert_allclose(base[0, 0, :10], pert[0, 0, :10],
                               rtol=1e-5, atol=1e-5)
    assert np.abs(base[0, 0, 10:] - pert[0, 0, 10:]).max() > 1e-3


def test_attention_softmax_rows_sum_to_one():
    """Uniform V ⇒ output equals V row regardless of scores."""
    rng = np.random.default_rng(14)
    q, k = randn(rng, (1, 1, 8, 4)), randn(rng, (1, 1, 8, 4))
    v = jnp.ones((1, 1, 8, 4))
    out = np.asarray(attn.attention(q, k, v, causal=False))
    np.testing.assert_allclose(out, 1.0, rtol=1e-5, atol=1e-5)
