"""L2 correctness: model graphs — shapes, grads, loss behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    batch = []
    for _, shape, dt in model.batch_spec(cfg):
        if dt == "i32":
            batch.append(jnp.asarray(
                rng.integers(0, cfg.vocab, size=shape, dtype=np.int32)))
        else:
            batch.append(jnp.asarray(rng.normal(size=shape).astype(np.float32)))
    return batch


TINY = ["lm-tiny", "seq2seq-tiny", "vit-tiny"]


@pytest.mark.parametrize("name", TINY)
def test_train_step_shapes(name):
    cfg = model.CONFIGS[name]
    p = model.init_params(cfg, 0)
    order = model.param_order(cfg)
    out = model.make_train_step(cfg)(*[p[n] for n in order], *make_batch(cfg))
    assert out[0].shape == ()
    assert len(out) == 1 + len(order)
    spec = model.init_spec(cfg)
    for name_, g in zip(order, out[1:]):
        assert g.shape == spec[name_][0], name_


@pytest.mark.parametrize("name", TINY)
def test_grads_finite_nonzero(name):
    cfg = model.CONFIGS[name]
    p = model.init_params(cfg, 1)
    order = model.param_order(cfg)
    out = model.make_train_step(cfg)(*[p[n] for n in order], *make_batch(cfg, 1))
    assert np.isfinite(float(out[0]))
    total = 0.0
    for g in out[1:]:
        arr = np.asarray(g)
        assert np.isfinite(arr).all()
        total += float(np.abs(arr).sum())
    assert total > 0.0


@pytest.mark.parametrize("name", TINY)
def test_initial_loss_near_uniform(name):
    """At init, the classifier should be near ln(vocab) (uniform predictions)."""
    cfg = model.CONFIGS[name]
    p = model.init_params(cfg, 2)
    order = model.param_order(cfg)
    out = model.make_loss_fn(cfg)(*[p[n] for n in order], *make_batch(cfg, 2))
    expected = np.log(cfg.vocab)
    assert abs(float(out[0]) - expected) < 0.35 * expected


@pytest.mark.parametrize("name", TINY)
def test_eval_matches_train_loss(name):
    cfg = model.CONFIGS[name]
    p = model.init_params(cfg, 3)
    order = model.param_order(cfg)
    args = [p[n] for n in order] + make_batch(cfg, 3)
    l_train = float(model.make_train_step(cfg)(*args)[0])
    l_eval = float(model.make_loss_fn(cfg)(*args)[0])
    assert abs(l_train - l_eval) < 1e-5


def test_sgd_steps_reduce_loss_lm():
    """A few SGD steps on a fixed batch must reduce the loss (the graph is
    actually trainable end-to-end through the Pallas attention VJP)."""
    cfg = model.CONFIGS["lm-tiny"]
    p = model.init_params(cfg, 4)
    order = model.param_order(cfg)
    batch = make_batch(cfg, 4)
    step = jax.jit(model.make_train_step(cfg))
    flat = [p[n] for n in order]
    first = None
    for _ in range(5):
        out = step(*flat, *batch)
        loss = float(out[0])
        if first is None:
            first = loss
        flat = [w - 0.5 * g for w, g in zip(flat, out[1:])]
    assert loss < first - 0.05, (first, loss)


def test_param_order_is_sorted_and_stable():
    for name in TINY:
        cfg = model.CONFIGS[name]
        order = model.param_order(cfg)
        assert order == sorted(order)
        assert order == model.param_order(cfg)


def test_param_counts_match_spec():
    for name, cfg in model.CONFIGS.items():
        spec = model.init_spec(cfg)
        n = sum(int(np.prod(s[0])) for s in spec.values())
        assert n == model.param_count(cfg), name


def test_lm_100m_is_about_100m():
    assert 80e6 < model.param_count(model.CONFIGS["lm-100m"]) < 130e6


def test_seq2seq_decoder_sees_encoder():
    """Cross-attention must actually wire encoder → decoder: changing the
    source sequence changes the loss."""
    cfg = model.CONFIGS["seq2seq-tiny"]
    p = model.init_params(cfg, 5)
    order = model.param_order(cfg)
    src, tgt_in, tgt_out = make_batch(cfg, 5)
    f = model.make_loss_fn(cfg)
    l1 = float(f(*[p[n] for n in order], src, tgt_in, tgt_out)[0])
    src2 = (src + 7) % cfg.vocab
    l2 = float(f(*[p[n] for n in order], src2, tgt_in, tgt_out)[0])
    assert abs(l1 - l2) > 1e-6


def test_causal_lm_ignores_future_tokens():
    """Loss on position i must not depend on tokens > i: compare grads of
    per-position loss — cheap proxy: perturbing the last input token must not
    change logits at earlier positions. Exercised via loss on prefix."""
    cfg = model.CONFIGS["lm-tiny"]
    p = model.init_params(cfg, 6)
    tokens = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    tok2 = tokens.at[:, -1].set(5)

    def logits(tok):
        x = p["embed/tok"][tok] + p["embed/pos"][None, :, :]
        for i in range(cfg.n_layers):
            x = model._block(p, f"dec{i:02d}", x, cfg.n_heads, causal=True)
        x = model.rms_norm(x, p["final_ln/scale"])
        return x @ p["head/w"]

    a = np.asarray(logits(tokens))[:, :-1]
    b = np.asarray(logits(tok2))[:, :-1]
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
