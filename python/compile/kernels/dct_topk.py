"""Layer-1 Pallas kernels for DeMo's chunked DCT momentum transform.

The DeMo replicator (Peng et al. 2024; DeToNATION §Methods) extracts the
"fast-moving" momentum components by (1) reshaping the flat momentum into
(n_chunks, chunk), (2) applying a DCT-II per chunk, (3) keeping the top-k
coefficients per chunk by magnitude.  The inverse path is a DCT-III.

Hardware adaptation (DESIGN.md §6): the paper's CUDA implementation maps
chunks to threadblocks.  On TPU, the natural shape is a *batched small
matmul against the DCT basis*: we tile a BLOCK of chunks into VMEM via
BlockSpec and compute ``(BLOCK, chunk) @ (chunk, chunk)`` on the MXU.
Chunk sizes used by the paper (16..256) divide into 128-lane tiles, and
one grid step streams one chunk-block HBM→VMEM — the BlockSpec analogue
of the paper's threadblock sweep.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the same artifact runs
under the Rust PJRT-CPU runtime.  Correctness vs ``ref.py`` is asserted by
python/tests/test_kernel.py (hypothesis sweeps shapes/k/chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# One grid step transforms this many chunks.  128 rows keeps the MXU
# operand (BLOCK, chunk) aligned with the 128x128 systolic array for every
# paper chunk size; VMEM footprint at chunk=256 is 128*256*4B*2 = 256 KiB.
DEFAULT_BLOCK = 128


def _dct_matmul_kernel(x_ref, basis_ref, o_ref):
    """o = x @ basis^T for one VMEM-resident block of chunks.

    ``basis`` is the orthonormal DCT-II matrix; passing its transpose
    flipped (DCT-III) reuses the identical kernel for the inverse.
    """
    o_ref[...] = jnp.dot(
        x_ref[...], basis_ref[...].T, preferred_element_type=jnp.float32
    )


def _blocked_transform(x: jnp.ndarray, basis: jnp.ndarray, block: int) -> jnp.ndarray:
    """Run the DCT matmul kernel over (n_chunks, chunk) in blocks of rows."""
    n_chunks, chunk = x.shape
    if n_chunks % block != 0:
        # Pad the chunk axis up to a whole number of blocks; the pad rows
        # transform to garbage we slice off.  Keeps BlockSpec static.
        pad = block - n_chunks % block
        x = jnp.pad(x, ((0, pad), (0, 0)))
    padded_chunks = x.shape[0]
    grid = (padded_chunks // block,)
    out = pl.pallas_call(
        _dct_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((padded_chunks, chunk), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, chunk), lambda i: (i, 0)),
            pl.BlockSpec((chunk, chunk), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, chunk), lambda i: (i, 0)),
        interpret=True,
    )(x, basis)
    return out[:n_chunks]


@functools.partial(jax.jit, static_argnames=("chunk", "block"))
def chunked_dct2(x: jnp.ndarray, chunk: int, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Chunked DCT-II of flat ``x`` → (n_chunks, chunk) coefficients."""
    basis = ref.dct_basis(chunk, jnp.float32)
    return _blocked_transform(x.reshape(-1, chunk), basis, block)


@functools.partial(jax.jit, static_argnames=("chunk", "block"))
def chunked_dct3(c: jnp.ndarray, chunk: int, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Chunked DCT-III (inverse): (n_chunks, chunk) coefficients → flat x."""
    basis = ref.dct_basis(chunk, jnp.float32)
    # DCT-III is multiplication by basis (not basis^T): reuse the kernel by
    # handing it the transposed matrix.
    out = _blocked_transform(c.reshape(-1, chunk), basis.T, block)
    return out.reshape(-1)


def _topk_mask(c: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-row top-k-|.| mask via a sort-based threshold.

    Deliberately NOT jax.lax.top_k: that lowers to the `topk(...,
    largest=true)` HLO op which the xla_extension 0.5.1 text parser (the
    Rust runtime's XLA) rejects; `sort` is classic HLO and round-trips.
    Ties at the threshold admit >k entries per row — measure-zero for the
    float momentum data this runs on (the Rust side breaks ties by index).
    """
    n = c.shape[-1]
    if k >= n:
        return jnp.ones_like(c, dtype=bool)
    a = jnp.abs(c)
    thresh = jnp.sort(a, axis=-1)[..., n - k : n - k + 1]
    return a >= thresh


@functools.partial(jax.jit, static_argnames=("chunk", "k", "sign", "block"))
def extract_fast_components(
    m: jnp.ndarray, chunk: int, k: int, sign: bool, block: int = DEFAULT_BLOCK
):
    """Full DeMo extraction: DCT-II → top-k mask → residual + transmit.

    Returns (q, m_next):
      q       — flat decoded transmit vector (signed if ``sign``),
      m_next  — flat residual momentum (true kept component removed).

    The two DCT passes run on the Pallas kernel; masking/top-k run as
    plain XLA ops fused around it.  This whole function is what
    ``aot.py`` lowers into the ``dct_extract_*`` artifacts used for
    Rust↔Python cross-validation.
    """
    c = chunked_dct2(m, chunk, block)
    mask = _topk_mask(c, k)
    kept = jnp.where(mask, c, 0.0)
    m_next = m - chunked_dct3(kept, chunk, block)
    tx = jnp.sign(kept) if sign else kept
    q = chunked_dct3(tx, chunk, block)
    return q, m_next
