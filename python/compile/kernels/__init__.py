# L1: Pallas kernels for the paper's compute hot-spots.
#   dct_topk   — chunked DCT-II/III + top-k extraction (DeMo replicator)
#   attention  — fused scaled-dot-product attention (all L2 transformers)
#   ref        — pure-jnp oracles for both
from . import attention, dct_topk, ref  # noqa: F401
