"""Layer-1 Pallas fused attention kernel.

Every transformer in model.py (decoder LM, encoder-decoder, ViT) routes
its scaled-dot-product attention through this kernel, so the model's
compute hot-spot lowers through Pallas into the AOT HLO artifact.

Shape strategy (DESIGN.md §6): one grid cell = one (batch·head).  The
whole (S, D) Q/K/V tiles and the (S, T) score tile live in VMEM — valid
for every experiment in the paper reproduction (S ≤ 512 → score tile
≤ 1 MiB ≪ 16 MiB VMEM).  QKᵀ and the weighted sum both hit the MXU; the
softmax row pass is VPU work on the VMEM-resident tile.  This is the
TPU re-think of flash-attention-style GPU tiling: at these sizes no
streaming softmax is needed, one block per head is already roofline.

``interpret=True``: CPU PJRT cannot run Mosaic custom-calls; interpret
mode lowers to portable HLO (see dct_topk.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_scores(q, k, scale: float, causal: bool):
    """Masked, scaled, row-softmaxed score tile (shared by fwd + bwd)."""
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        s, t = scores.shape
        row = jax.lax.broadcasted_iota(jnp.int32, (s, t), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, t), 1)
        scores = jnp.where(row >= col, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool):
    """Fused attention for one (batch·head): softmax(Q Kᵀ · scale) V."""
    w = _softmax_scores(q_ref[0], k_ref[0], scale, causal)
    o_ref[0] = jnp.dot(w, v_ref[0], preferred_element_type=jnp.float32)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref,
                     *, scale: float, causal: bool):
    """Fused attention backward for one (batch·head).

    Recomputes the softmax tile in VMEM (flash-style: cheaper than
    spilling the (S,T) weights to HBM) and emits dQ/dK/dV:
        dV = Wᵀ dO
        dS = W ∘ (dO Vᵀ − rowsum(dO Vᵀ ∘ W))
        dQ = dS K · scale,  dK = dSᵀ Q · scale
    """
    q, k, v, do = q_ref[0], k_ref[0], v_ref[0], do_ref[0]
    w = _softmax_scores(q, k, scale, causal)
    dv_ref[0] = jnp.dot(w.T, do, preferred_element_type=jnp.float32)
    dw = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = w * (dw - jnp.sum(dw * w, axis=-1, keepdims=True))
    dq_ref[0] = jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale
    dk_ref[0] = jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale


def _flat_specs(n: int, s: int, t: int, d: int):
    qspec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    kspec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return qspec, kspec


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _attention_flat(qf, kf, vf, causal: bool):
    """Attention on flattened (B·H, S|T, D) operands; fwd Pallas kernel."""
    n, s, d = qf.shape
    t = kf.shape[1]
    scale = 1.0 / math.sqrt(d)
    qspec, kspec = _flat_specs(n, s, t, d)
    return pl.pallas_call(
        functools.partial(_attn_fwd_kernel, scale=scale, causal=causal),
        out_shape=jax.ShapeDtypeStruct((n, s, d), jnp.float32),
        grid=(n,),
        in_specs=[qspec, kspec, kspec],
        out_specs=qspec,
        interpret=True,
    )(qf, kf, vf)


def _attention_flat_fwd(qf, kf, vf, causal: bool):
    return _attention_flat(qf, kf, vf, causal), (qf, kf, vf)


def _attention_flat_bwd(causal: bool, res, do):
    qf, kf, vf = res
    n, s, d = qf.shape
    t = kf.shape[1]
    scale = 1.0 / math.sqrt(d)
    qspec, kspec = _flat_specs(n, s, t, d)
    dq, dk, dv = pl.pallas_call(
        functools.partial(_attn_bwd_kernel, scale=scale, causal=causal),
        out_shape=(
            jax.ShapeDtypeStruct((n, s, d), jnp.float32),
            jax.ShapeDtypeStruct((n, t, d), jnp.float32),
            jax.ShapeDtypeStruct((n, t, d), jnp.float32),
        ),
        grid=(n,),
        in_specs=[qspec, kspec, kspec, qspec],
        out_specs=(qspec, kspec, kspec),
        interpret=True,
    )(qf, kf, vf, do)
    return dq, dk, dv


_attention_flat.defvjp(_attention_flat_fwd, _attention_flat_bwd)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = False) -> jnp.ndarray:
    """Multi-head attention via the Pallas kernels (fwd + custom-VJP bwd).

    q: (B, H, S, D); k, v: (B, H, T, D).  Returns (B, H, S, D).
    Causal requires S == T (decoder self-attention).
    """
    b, h, s, d = q.shape
    t = k.shape[2]
    if causal and s != t:
        raise ValueError(f"causal attention needs S==T, got S={s} T={t}")
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    return _attention_flat(qf, kf, vf, causal).reshape(b, h, s, d)
