"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has a line-for-line mathematical twin
here, written with plain jax.numpy only.  pytest (python/tests/) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and
oracle; the Rust side additionally cross-validates its native DCT against
the AOT-compiled encode/decode artifacts built from these kernels.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def dct_basis(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal DCT-II basis matrix B with shape (n, n).

    Row k is the k-th DCT-II basis vector:
        B[k, i] = s_k * cos(pi/n * (i + 0.5) * k)
    with s_0 = sqrt(1/n) and s_k = sqrt(2/n) for k > 0, so that B is
    orthogonal (B @ B.T = I) and DCT-III (the inverse) is simply B.T.

    The same matrix (bit-identical up to f32 rounding) is generated on the
    Rust side in ``rust/src/dct``; tests pin a few entries numerically to
    guard against convention drift (scaling/normalization mismatches are
    the classic DCT bug).
    """
    i = np.arange(n)
    k = np.arange(n)[:, None]
    b = np.cos(math.pi / n * (i[None, :] + 0.5) * k)
    scale = np.full((n, 1), math.sqrt(2.0 / n))
    scale[0, 0] = math.sqrt(1.0 / n)
    return jnp.asarray(b * scale, dtype=dtype)


def dct2_ref(x: jnp.ndarray, basis: jnp.ndarray) -> jnp.ndarray:
    """DCT-II of each row of ``x`` (shape (..., n)): coefficients c = x B^T."""
    return x @ basis.T


def dct3_ref(c: jnp.ndarray, basis: jnp.ndarray) -> jnp.ndarray:
    """DCT-III (inverse of orthonormal DCT-II) of each row: x = c B."""
    return c @ basis


def chunked_dct2_ref(x: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """DeMo's chunked transform: reshape flat x to (n/chunk, chunk), DCT rows."""
    n = x.shape[-1]
    assert n % chunk == 0, f"len {n} not divisible by chunk {chunk}"
    b = dct_basis(chunk, x.dtype)
    return dct2_ref(x.reshape(-1, chunk), b)


def chunked_dct3_ref(c: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """Inverse of :func:`chunked_dct2_ref`; returns the flat vector."""
    b = dct_basis(chunk, c.dtype)
    return dct3_ref(c.reshape(-1, chunk), b).reshape(-1)


def topk_mask_ref(c: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-row boolean mask keeping the k largest-|.| coefficients.

    Ties are broken toward the lower index (stable argsort on -|c|),
    matching the Rust quickselect which orders by (|c| desc, idx asc).
    """
    n = c.shape[-1]
    if k >= n:
        return jnp.ones_like(c, dtype=bool)
    order = jnp.argsort(-jnp.abs(c), axis=-1, stable=True)
    keep = order[..., :k]
    mask = jnp.zeros(c.shape, dtype=bool)
    rows = jnp.arange(c.shape[0])[:, None]
    return mask.at[rows, keep].set(True)


def extract_fast_components_ref(m: jnp.ndarray, chunk: int, k: int, sign: bool):
    """DeMo ExtractFastComponents oracle.

    Input: flat momentum m (len divisible by chunk).
    Returns (q_flat, m_next_flat, kept) where
      * kept is the sparse (masked) DCT coefficient matrix,
      * q_flat is the decoded transmitted update (what every node adds in),
      * m_next = m - decode(kept) — the momentum keeps only its residual.
        Sign is applied to what is *transmitted*; the local subtraction
        removes the true component (matches the DeMo reference impl).
    """
    c = chunked_dct2_ref(m, chunk)
    mask = topk_mask_ref(c, k)
    kept = jnp.where(mask, c, 0.0)
    m_next = m - chunked_dct3_ref(kept, chunk)
    tx = jnp.sign(kept) if sign else kept
    q = chunked_dct3_ref(tx, chunk)
    return q, m_next, kept


def softmax_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool) -> jnp.ndarray:
    """Scaled dot-product attention oracle.

    q: (B, H, S, D), k/v: (B, H, T, D).  Causal masks future keys (needs
    S == T, i.e. self-attention).
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / math.sqrt(d)
    if causal:
        s, t = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((s, t), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    w = softmax_ref(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v)
