"""AOT pipeline: lower L2/L1 jax graphs to HLO text + manifests for Rust.

Interchange format is **HLO text**, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py and DESIGN.md §3).

Per model config this emits
    artifacts/<name>.train.hlo.txt   — (params..., batch) -> (loss, grads...)
    artifacts/<name>.eval.hlo.txt    — (params..., batch) -> (loss,)
    artifacts/<name>.meta.json       — parameter manifest + batch spec
and per DCT-extraction config
    artifacts/dct_extract_<len>_c<chunk>_k<k>[_sign].hlo.txt
    (flat momentum) -> (q, m_next)   — Rust↔Pallas cross-validation +
                                       optional extraction offload.

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import dct_topk

# Extraction artifacts: (flat_len, chunk, k, sign).  flat_len 16384 is the
# shard-slab size the Rust tests/benches cross-validate against; chunk/k
# cover the paper's Fig 8/11 sweep corners.
EXTRACT_CONFIGS = [
    (16384, 64, 8, True),
    (16384, 64, 8, False),
    (16384, 32, 4, True),
    (16384, 128, 16, True),
]

# Default artifact set: everything the tests/examples/benches need.
# lm-100m is opt-in (--models lm-100m) — it lowers fine but compiles for
# minutes under PJRT-CPU, so the default build skips it.
DEFAULT_MODELS = [
    "lm-tiny", "lm-small", "seq2seq-tiny", "seq2seq-small",
    "vit-tiny", "vit-small",
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big dense constants as ``constant({...})`` and the 0.5.1 text
    parser silently reads those back as ZEROS — e.g. the DCT basis matrix
    baked into the extraction artifacts would vanish. A regression test in
    python/tests/test_aot.py greps for the elision marker.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def emit_model(cfg: model.ModelConfig, out_dir: str) -> None:
    """Lower train+eval steps for one config and write HLO + manifest."""
    t0 = time.time()
    args = model.example_args(cfg)

    train = jax.jit(model.make_train_step(cfg))
    train_hlo = to_hlo_text(train.lower(*args))
    with open(os.path.join(out_dir, f"{cfg.name}.train.hlo.txt"), "w") as f:
        f.write(train_hlo)

    ev = jax.jit(model.make_loss_fn(cfg))
    eval_hlo = to_hlo_text(ev.lower(*args))
    with open(os.path.join(out_dir, f"{cfg.name}.eval.hlo.txt"), "w") as f:
        f.write(eval_hlo)

    spec = model.init_spec(cfg)
    manifest = {
        "name": cfg.name,
        "family": cfg.family,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "d_ff": cfg.d_ff,
        "seq": cfg.seq,
        "src_seq": cfg.src_seq,
        "patch_dim": cfg.patch_dim,
        "batch": cfg.batch,
        "param_count": int(model.param_count(cfg)),
        "params": [
            {
                "name": n,
                "shape": list(spec[n][0]),
                "init": list(spec[n][1]),
            }
            for n in model.param_order(cfg)
        ],
        "batch_inputs": [
            {"name": n, "shape": list(s), "dtype": d}
            for n, s, d in model.batch_spec(cfg)
        ],
    }
    with open(os.path.join(out_dir, f"{cfg.name}.meta.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {cfg.name}: {manifest['param_count']:,} params, "
          f"train hlo {len(train_hlo)//1024} KiB  ({time.time()-t0:.1f}s)",
          flush=True)


def emit_extract(flat_len: int, chunk: int, k: int, sign: bool,
                 out_dir: str) -> None:
    """Lower the Pallas DCT extraction for one (len, chunk, k, sign)."""
    fn = jax.jit(
        lambda m: dct_topk.extract_fast_components(m, chunk, k, sign)
    )
    hlo = to_hlo_text(fn.lower(jax.ShapeDtypeStruct((flat_len,), jnp.float32)))
    suffix = "_sign" if sign else ""
    name = f"dct_extract_{flat_len}_c{chunk}_k{k}{suffix}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    print(f"  {name}: {len(hlo)//1024} KiB", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--models", nargs="*", default=None,
                    help=f"model configs (default: {' '.join(DEFAULT_MODELS)}; "
                         f"all known: {' '.join(model.CONFIGS)})")
    ap.add_argument("--skip-extract", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = args.models if args.models is not None else DEFAULT_MODELS
    print(f"emitting artifacts to {os.path.abspath(args.out)}", flush=True)
    for name in names:
        if name not in model.CONFIGS:
            print(f"unknown model config {name!r}", file=sys.stderr)
            sys.exit(2)
        emit_model(model.CONFIGS[name], args.out)
    if not args.skip_extract:
        for flat_len, chunk, k, sign in EXTRACT_CONFIGS:
            emit_extract(flat_len, chunk, k, sign, args.out)
    print("done", flush=True)


if __name__ == "__main__":
    main()
