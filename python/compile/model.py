"""Layer-2 JAX models: the compute graphs AOT-lowered for the Rust runtime.

Three transformer families mirror the paper's three evaluation domains
(DESIGN.md §2 substitution table):

  * ``lm``      — decoder-only causal LM        (OLMo2 stand-in, Figs 3–6)
  * ``seq2seq`` — encoder-decoder translation   (T5 stand-in,   Figs 1,2a,8–15)
  * ``vit``     — vision transformer classifier (ViT-B stand-in, Figs 2b,16)

All attention goes through the Layer-1 Pallas kernel
(:mod:`compile.kernels.attention`), so the hot-spot lowers through Pallas
into the same HLO artifact.

Conventions
-----------
Parameters are a flat ``{name: array}`` dict with ``/``-separated names;
the AOT manifest orders them by sorted name, and the Rust side constructs
and owns the actual parameter buffers (python never initializes state at
runtime — ``init_spec`` only *describes* shapes and initializers).

``train_step(params, batch) -> (loss, grads)`` is the single artifact
entry point per model config.  The Rust coordinator implements the
optimizer and all communication; this graph is pure compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + batch spec for one AOT artifact."""

    name: str
    family: str          # "lm" | "seq2seq" | "vit"
    vocab: int           # vocab size (lm/seq2seq) or num classes (vit)
    d_model: int
    n_heads: int
    n_layers: int        # decoder layers (and encoder layers for seq2seq)
    d_ff: int
    seq: int             # sequence length (lm), target length (seq2seq),
                         # or number of patches (vit)
    src_seq: int = 0     # source length (seq2seq only)
    patch_dim: int = 0   # flattened patch size (vit only)
    batch: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The registry every artifact is generated from.  Sizes are chosen so the
# loss-curve experiments run in CPU-minutes; ``lm-100m`` is the ~100M-param
# end-to-end config used by examples/train_lm.rs --model lm-100m.
CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("lm-tiny", "lm", vocab=256, d_model=64, n_heads=4,
                    n_layers=2, d_ff=256, seq=64, batch=8),
        ModelConfig("lm-small", "lm", vocab=512, d_model=192, n_heads=6,
                    n_layers=4, d_ff=768, seq=128, batch=8),
        ModelConfig("lm-100m", "lm", vocab=16384, d_model=640, n_heads=10,
                    n_layers=14, d_ff=2560, seq=256, batch=4),
        ModelConfig("seq2seq-tiny", "seq2seq", vocab=256, d_model=64,
                    n_heads=4, n_layers=2, d_ff=256, seq=48, src_seq=48,
                    batch=8),
        ModelConfig("seq2seq-small", "seq2seq", vocab=512, d_model=128,
                    n_heads=8, n_layers=3, d_ff=512, seq=64, src_seq=64,
                    batch=8),
        ModelConfig("vit-tiny", "vit", vocab=16, d_model=64, n_heads=4,
                    n_layers=2, d_ff=256, seq=64, patch_dim=48, batch=8),
        ModelConfig("vit-small", "vit", vocab=32, d_model=128, n_heads=8,
                    n_layers=4, d_ff=512, seq=64, patch_dim=48, batch=8),
    ]
}


# --------------------------------------------------------------------------
# Init specs — shapes + initializer descriptions consumed by Rust
# --------------------------------------------------------------------------

def _block_spec(prefix: str, cfg: ModelConfig, cross: bool) -> Dict[str, Tuple]:
    """Parameter spec for one pre-norm transformer block.

    Returns {name: (shape, init)} where init is ("normal", std) | ("zeros",)
    | ("ones",).
    """
    d, f = cfg.d_model, cfg.d_ff
    std = 0.02
    out_std = 0.02 / math.sqrt(2.0 * cfg.n_layers)  # GPT-2 style residual scaling
    spec = {
        f"{prefix}/ln1/scale": ((d,), ("ones",)),
        f"{prefix}/attn/wq": ((d, d), ("normal", std)),
        f"{prefix}/attn/wk": ((d, d), ("normal", std)),
        f"{prefix}/attn/wv": ((d, d), ("normal", std)),
        f"{prefix}/attn/wo": ((d, d), ("normal", out_std)),
        f"{prefix}/ln2/scale": ((d,), ("ones",)),
        f"{prefix}/ffn/w1": ((d, f), ("normal", std)),
        f"{prefix}/ffn/w2": ((f, d), ("normal", out_std)),
    }
    if cross:
        spec.update({
            f"{prefix}/lnx/scale": ((d,), ("ones",)),
            f"{prefix}/xattn/wq": ((d, d), ("normal", std)),
            f"{prefix}/xattn/wk": ((d, d), ("normal", std)),
            f"{prefix}/xattn/wv": ((d, d), ("normal", std)),
            f"{prefix}/xattn/wo": ((d, d), ("normal", out_std)),
        })
    return spec


def init_spec(cfg: ModelConfig) -> Dict[str, Tuple]:
    """Full parameter spec {name: (shape, init)} for a config.

    The Rust runtime materializes parameters from this spec (same names,
    sorted order = flat artifact order) using its own seeded RNG.
    """
    d = cfg.d_model
    std = 0.02
    spec: Dict[str, Tuple] = {}
    if cfg.family == "lm":
        spec["embed/tok"] = ((cfg.vocab, d), ("normal", std))
        spec["embed/pos"] = ((cfg.seq, d), ("normal", std))
        for i in range(cfg.n_layers):
            spec.update(_block_spec(f"dec{i:02d}", cfg, cross=False))
        spec["final_ln/scale"] = ((d,), ("ones",))
        spec["head/w"] = ((d, cfg.vocab), ("normal", std))
    elif cfg.family == "seq2seq":
        spec["embed/tok"] = ((cfg.vocab, d), ("normal", std))
        spec["embed/pos_src"] = ((cfg.src_seq, d), ("normal", std))
        spec["embed/pos_tgt"] = ((cfg.seq, d), ("normal", std))
        for i in range(cfg.n_layers):
            spec.update(_block_spec(f"enc{i:02d}", cfg, cross=False))
        for i in range(cfg.n_layers):
            spec.update(_block_spec(f"dec{i:02d}", cfg, cross=True))
        spec["enc_ln/scale"] = ((d,), ("ones",))
        spec["final_ln/scale"] = ((d,), ("ones",))
        spec["head/w"] = ((d, cfg.vocab), ("normal", std))
    elif cfg.family == "vit":
        spec["embed/patch"] = ((cfg.patch_dim, d), ("normal", std))
        spec["embed/pos"] = ((cfg.seq + 1, d), ("normal", std))  # +1 CLS
        spec["embed/cls"] = ((1, d), ("normal", std))
        for i in range(cfg.n_layers):
            spec.update(_block_spec(f"enc{i:02d}", cfg, cross=False))
        spec["final_ln/scale"] = ((d,), ("ones",))
        spec["head/w"] = ((d, cfg.vocab), ("normal", std))
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return spec


def param_order(cfg: ModelConfig) -> List[str]:
    """Canonical flat ordering of parameters (sorted names)."""
    return sorted(init_spec(cfg).keys())


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Reference initializer (tests only — Rust owns runtime init)."""
    key = jax.random.PRNGKey(seed)
    spec = init_spec(cfg)
    params: Params = {}
    for name in param_order(cfg):
        shape, init = spec[name]
        key, sub = jax.random.split(key)
        if init[0] == "normal":
            params[name] = init[1] * jax.random.normal(sub, shape, jnp.float32)
        elif init[0] == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif init[0] == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            raise ValueError(init)
    return params


# --------------------------------------------------------------------------
# Model pieces
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm (pre-norm blocks; OLMo2/T5-style, no bias)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _unheads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _attn(p: Params, prefix: str, x: jnp.ndarray, kv: jnp.ndarray,
          n_heads: int, causal: bool) -> jnp.ndarray:
    """One attention sub-block (self if kv is x, cross otherwise)."""
    q = _heads(x @ p[f"{prefix}/wq"], n_heads)
    k = _heads(kv @ p[f"{prefix}/wk"], n_heads)
    v = _heads(kv @ p[f"{prefix}/wv"], n_heads)
    o = attention(q, k, v, causal=causal)  # Layer-1 Pallas kernel
    return _unheads(o) @ p[f"{prefix}/wo"]


def _ffn(p: Params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p[f"{prefix}/w1"]) @ p[f"{prefix}/w2"]


def _block(p: Params, prefix: str, x: jnp.ndarray, n_heads: int,
           causal: bool, enc: jnp.ndarray | None = None) -> jnp.ndarray:
    """Pre-norm transformer block; optional cross-attention on ``enc``."""
    h = rms_norm(x, p[f"{prefix}/ln1/scale"])
    x = x + _attn(p, f"{prefix}/attn", h, h, n_heads, causal)
    if enc is not None:
        x = x + _attn(p, f"{prefix}/xattn",
                      rms_norm(x, p[f"{prefix}/lnx/scale"]), enc,
                      n_heads, causal=False)
    x = x + _ffn(p, f"{prefix}/ffn", rms_norm(x, p[f"{prefix}/ln2/scale"]))
    return x


def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean token-level cross-entropy; logits (..., V), targets int (...)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Losses per family
# --------------------------------------------------------------------------

def lm_loss(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray) -> jnp.ndarray:
    """Causal LM loss.  tokens/targets: int32 (B, S)."""
    x = p["embed/tok"][tokens] + p["embed/pos"][None, :, :]
    for i in range(cfg.n_layers):
        x = _block(p, f"dec{i:02d}", x, cfg.n_heads, causal=True)
    x = rms_norm(x, p["final_ln/scale"])
    return _xent(x @ p["head/w"], targets)


def seq2seq_loss(p: Params, cfg: ModelConfig, src: jnp.ndarray,
                 tgt_in: jnp.ndarray, tgt_out: jnp.ndarray) -> jnp.ndarray:
    """Encoder-decoder translation loss (teacher forcing).

    src: int32 (B, S_src); tgt_in/tgt_out: int32 (B, S_tgt).
    """
    e = p["embed/tok"][src] + p["embed/pos_src"][None, :, :]
    for i in range(cfg.n_layers):
        e = _block(p, f"enc{i:02d}", e, cfg.n_heads, causal=False)
    e = rms_norm(e, p["enc_ln/scale"])
    x = p["embed/tok"][tgt_in] + p["embed/pos_tgt"][None, :, :]
    for i in range(cfg.n_layers):
        x = _block(p, f"dec{i:02d}", x, cfg.n_heads, causal=True, enc=e)
    x = rms_norm(x, p["final_ln/scale"])
    return _xent(x @ p["head/w"], tgt_out)


def vit_loss(p: Params, cfg: ModelConfig, patches: jnp.ndarray,
             labels: jnp.ndarray) -> jnp.ndarray:
    """ViT classification loss.  patches: f32 (B, P, patch_dim); labels (B,)."""
    x = patches @ p["embed/patch"]
    cls = jnp.broadcast_to(p["embed/cls"], (x.shape[0], 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + p["embed/pos"][None, :, :]
    for i in range(cfg.n_layers):
        x = _block(p, f"enc{i:02d}", x, cfg.n_heads, causal=False)
    x = rms_norm(x, p["final_ln/scale"])
    logits = x[:, 0, :] @ p["head/w"]
    return _xent(logits, labels)


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """(name, shape, dtype) of the batch inputs, in artifact argument order."""
    b = cfg.batch
    if cfg.family == "lm":
        return [("tokens", (b, cfg.seq), "i32"), ("targets", (b, cfg.seq), "i32")]
    if cfg.family == "seq2seq":
        return [("src", (b, cfg.src_seq), "i32"),
                ("tgt_in", (b, cfg.seq), "i32"),
                ("tgt_out", (b, cfg.seq), "i32")]
    if cfg.family == "vit":
        return [("patches", (b, cfg.seq, cfg.patch_dim), "f32"),
                ("labels", (b,), "i32")]
    raise ValueError(cfg.family)


def make_train_step(cfg: ModelConfig):
    """Build ``train_step(*flat_params, *batch) -> (loss, *flat_grads)``.

    Flat positional signature (manifest order) so the Rust runtime can
    marshal plain literals without pytree knowledge.
    """
    order = param_order(cfg)
    loss_fn = {"lm": lm_loss, "seq2seq": seq2seq_loss, "vit": vit_loss}[cfg.family]
    n_params = len(order)

    def train_step(*args):
        params = dict(zip(order, args[:n_params]))
        batch = args[n_params:]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, *batch)
        )(params)
        return (loss,) + tuple(grads[name] for name in order)

    return train_step


def make_loss_fn(cfg: ModelConfig):
    """Build ``eval_step(*flat_params, *batch) -> (loss,)`` (validation)."""
    order = param_order(cfg)
    loss_fn = {"lm": lm_loss, "seq2seq": seq2seq_loss, "vit": vit_loss}[cfg.family]
    n_params = len(order)

    def eval_step(*args):
        params = dict(zip(order, args[:n_params]))
        return (loss_fn(params, cfg, *args[n_params:]),)

    return eval_step


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs for jax.jit(...).lower(...)."""
    spec = init_spec(cfg)
    args = [jax.ShapeDtypeStruct(spec[n][0], jnp.float32) for n in param_order(cfg)]
    for _, shape, dt in batch_spec(cfg):
        args.append(jax.ShapeDtypeStruct(shape, jnp.int32 if dt == "i32" else jnp.float32))
    return args


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s[0]))) for s in init_spec(cfg).values())
