//! Figs 5/6 scenario: scaling FlexDeMo to 64 nodes.
//!
//!     cargo run --release --example scaling -- --nodes 64 --steps 100
//!
//! The comm clock models all 64 nodes exactly; gradient streams are
//! deduplicated to `--streams` real fwd/bwd executions per step
//! (DESIGN.md §2 substitution). Paper findings reproduced: DeMo's
//! blocking all-gather stops scaling (time per step grows ~linearly with
//! node count) while Random stays ~64% faster than the conventional
//! full-sync baseline.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::util::argparse::ArgParser;
use detonation::util::{fmt_bytes, fmt_secs};

fn main() -> Result<()> {
    let args = ArgParser::new("scaling", "64-node scaling study")
        .opt("model", "lm-tiny", "artifact name")
        .opt("nodes", "64", "node count")
        .opt("accels", "4", "accelerators per node")
        .opt("steps", "100", "training steps")
        .opt("streams", "8", "distinct gradient streams computed")
        .flag("quick", "artifact-free CI smoke shape (synthetic-lm, 8 nodes)")
        .parse_env();
    let quick = args.flag("quick");

    let rt = runtime()?;
    let mut exp = Experiment::new("scaling", &results_root());

    let base = ExperimentConfig {
        model: if quick {
            "synthetic-lm".into()
        } else {
            args.string("model")
        },
        nodes: if quick { 8 } else { args.usize("nodes") },
        accels_per_node: if quick { 2 } else { args.usize("accels") },
        steps: if quick { 6 } else { args.u64("steps") },
        compute_streams: if quick { 4 } else { args.usize("streams") },
        lr: 1e-3,
        ..Default::default()
    };
    // Latency-scaled paper network (OLMo2-1B reference) — preserves the
    // paper's time ratios exactly (see NetModel::paper_scaled).
    let mut base = base;
    let params = if quick {
        detonation::runtime::Manifest::synthetic(&base.model).param_count
    } else {
        let meta = std::fs::read_to_string(format!("artifacts/{}.meta.json", base.model))?;
        detonation::runtime::Manifest::parse(&meta)?.param_count
    };
    base.net = detonation::net::NetModel::paper_scaled(params, 1.2e9);

    for (opt, repl) in [
        ("demo-sgd", "demo:1/32"),
        ("demo-sgd", "random:1/32"),
        ("adamw", "full"),
    ] {
        let mut cfg = base.clone();
        cfg.apply_arg("opt", opt)?;
        cfg.apply_arg("repl", repl)?;
        exp.run(&rt, &cfg, Some(&format!("{opt}+{}", cfg.repl.label())))?;
    }

    println!("\n=== {}-node scaling ===\n", base.nodes);
    println!("{}", exp.finish()?);
    let t = |i: usize| exp.runs[i].mean_step_time();
    println!(
        "step time: demo {} | random {} | full-sync {}",
        fmt_secs(t(0)),
        fmt_secs(t(1)),
        fmt_secs(t(2))
    );
    println!(
        "random is {:.0}% faster than the conventional setup; demo is {:.2}x SLOWER than random \
         (blocking all-gather, linear in node count)",
        (1.0 - t(1) / t(2)) * 100.0,
        t(0) / t(1),
    );
    println!(
        "inter-node traffic: demo {} | random {} | full {}",
        fmt_bytes(exp.runs[0].total_inter_bytes()),
        fmt_bytes(exp.runs[1].total_inter_bytes()),
        fmt_bytes(exp.runs[2].total_inter_bytes()),
    );
    Ok(())
}
