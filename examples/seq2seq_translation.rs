//! The paper's T5 scenario (Figs 1/2a): replicator shoot-out on a
//! synthetic translation task with an encoder-decoder transformer.
//!
//!     cargo run --release --example seq2seq_translation -- --steps 200
//!
//! Runs DeMo / Random / Striding / DiLoCo replication under DeMo-SGD and
//! reports validation loss + bandwidth — the paper's headline finding is
//! that **Random wins on encoder-decoder translation**.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::replicate::ReplSpec;
use detonation::util::argparse::ArgParser;

fn main() -> Result<()> {
    let args = ArgParser::new("seq2seq_translation", "replicator comparison on translation")
        .opt("model", "seq2seq-tiny", "artifact name")
        .opt("steps", "200", "training steps")
        .opt("rate", "1/8", "compression rate (e.g. 1/8)")
        .flag("quick", "artifact-free CI smoke shape (synthetic-lm, 8 steps)")
        .parse_env();

    let rt = runtime()?;
    let mut exp = Experiment::new("seq2seq_translation", &results_root());
    let rate = args.str("rate").strip_prefix("1/").unwrap_or("8").to_string();
    let quick = args.flag("quick");
    let steps = if quick { 8 } else { args.u64("steps") };

    let base = ExperimentConfig {
        model: if quick {
            "synthetic-lm".into()
        } else {
            args.string("model")
        },
        nodes: 2,
        accels_per_node: 2,
        steps,
        val_every: (steps / 4).max(1),
        lr: 1e-3,
        ..Default::default()
    };

    for spec in [
        format!("demo:1/{rate}"),
        format!("random:1/{rate}"),
        format!("striding:1/{rate}"),
        format!("diloco:{rate}"),
    ] {
        let mut cfg = base.clone();
        cfg.repl = ReplSpec::parse(&spec)?;
        exp.run(&rt, &cfg, Some(&cfg.repl.label()))?;
    }

    println!("\n=== translation (encoder-decoder): replicator comparison ===\n");
    println!("{}", exp.finish()?);
    if let Some((label, loss)) = exp.best_val() {
        println!("best validation loss: {label} ({loss:.4})");
        println!("(paper Fig 2a: Random replication wins this architecture)");
    }
    Ok(())
}
