//! Sync-topology sweep: who talks to whom in each sync window.
//!
//! Trains the same DiLoCo group under each `--topology` on an
//! 8-node-pair cluster — the whole-group exchange (`full`), the ±1
//! neighbor ring, the per-window seeded perfect matching
//! (`random-pair`), and the rotating two-wide circulant fanout
//! (`hier:2`) — and prints what each connectivity buys: inter-node
//! bytes, the simulated time per step, and the per-member peer-set
//! sizes from the steps CSV.
//!
//!     cargo run --release --example topology_sweep
//!
//! The peer sets are pure hashes of (seed, step, shard), so every arm
//! is bit-reproducible, and `full` is bit-identical to not passing
//! `--topology` at all. Uses the in-process `synthetic-lm` surrogate,
//! so no artifacts are needed. The same sweep at bench scale
//! (g up to 64) writes `BENCH_topology.json`
//! (`cargo bench --bench topology`).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::metrics::sparkline;
use detonation::util::argparse::ArgParser;
use detonation::util::fmt_secs;

fn main() -> Result<()> {
    detonation::util::logging::init();
    let args = ArgParser::new("topology_sweep", "gossip vs full-group sync windows")
        .opt("period", "4", "DiLoCo sync period (steps)")
        .opt("nodes", "8", "replication group size (one rank per node)")
        .opt("steps", "48", "training steps per arm")
        .flag("quick", "CI smoke shape (3 sync windows per arm)")
        .parse_env();
    let period: u64 = args.str("period").parse()?;
    let nodes: usize = args.str("nodes").parse()?;
    let steps: u64 = if args.flag("quick") {
        3 * period
    } else {
        args.str("steps").parse()?
    };

    let rt = runtime()?;
    let mut exp = Experiment::new("topology_sweep", &results_root());

    let base = {
        let mut c = ExperimentConfig {
            model: "synthetic-lm".into(),
            nodes,
            accels_per_node: 1,
            steps,
            lr: 0.02,
            seed: 23,
            val_every: steps,
            val_batches: 8,
            compute_streams: 4,
            ..Default::default()
        };
        c.apply_arg("inter-mbps", "200")?;
        c.apply_arg("repl", &format!("diloco:{period}"))?;
        c
    };

    let arms: [&str; 4] = ["full", "ring", "random-pair", "hier:2"];
    for topo in arms {
        let mut c = base.clone();
        c.apply_arg("topology", topo)?;
        exp.run(&rt, &c, Some(&topo.replace(':', "")))?;
    }

    println!("\n=== DiLoCo sync windows by topology (period {period}, {nodes} nodes) ===\n");
    let full_step = exp.runs[0].mean_step_time();
    let full_bytes = exp.runs[0].total_inter_bytes() as f64;
    for run in &exp.runs {
        let losses: Vec<f64> = run.steps.iter().map(|r| r.loss).collect();
        // the last launch step's per-member peer-set sizes (empty under
        // full: the whole-group path never populates the column)
        let peers = run
            .steps
            .iter()
            .rev()
            .find(|r| !r.peer_set.is_empty())
            .map(|r| r.peer_set.clone())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<12} loss {}  t/step {:>9} ({:>5.2}x)  inter {:>5.2}x  peers {}",
            run.label,
            sparkline(&losses, 32),
            fmt_secs(run.mean_step_time()),
            run.mean_step_time() / full_step,
            run.total_inter_bytes() as f64 / full_bytes,
            peers,
        );
    }
    println!("{}", exp.finish()?);
    println!("CSV series in {}", exp.out_dir.display());
    Ok(())
}
