//! Async DiLoCo end to end: the staleness sweep.
//!
//! DiLoCo syncs every n-th step — the one scheme where the periodic
//! gather can run *concurrently* with local optimization. This example
//! trains the same model four ways on a throttled (100 Mbps) two-node
//! cluster — synchronous DiLoCo, then async DiLoCo with the averaged
//! delta applied `S ∈ {1, 2, 4}` steps late — and prints the trade the
//! `--staleness` knob buys: simulated time per step falls (local steps
//! keep running under the in-flight gather) while the validation loss
//! tracks how much bounded staleness the trajectory tolerated.
//!
//!     cargo run --release --example async_diloco
//!
//! Uses the in-process `synthetic-lm` surrogate, so no artifacts are
//! needed. The same sweep at bench scale writes
//! `BENCH_async_diloco.json` (`cargo bench --bench async_diloco`).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::metrics::sparkline;
use detonation::net::NetModel;
use detonation::util::argparse::ArgParser;
use detonation::util::fmt_secs;

fn main() -> Result<()> {
    detonation::util::logging::init();
    let args = ArgParser::new("async_diloco", "async DiLoCo staleness sweep")
        .opt("period", "8", "DiLoCo sync period (steps)")
        .opt("steps", "64", "training steps per arm")
        .flag("quick", "CI smoke shape (3 sync windows per arm)")
        .parse_env();
    let period: u64 = args.str("period").parse()?;
    let steps: u64 = if args.flag("quick") {
        3 * period
    } else {
        args.str("steps").parse()?
    };

    let rt = runtime()?;
    let mut exp = Experiment::new("async_diloco", &results_root());

    let base = {
        let mut c = ExperimentConfig {
            model: "synthetic-lm".into(),
            nodes: 2,
            accels_per_node: 2,
            steps,
            lr: 0.02,
            seed: 11,
            val_every: steps,
            val_batches: 8,
            net: NetModel::throttled(100.0),
            ..Default::default()
        };
        c.apply_arg("repl", &format!("diloco:{period}"))?;
        c
    };

    exp.run(&rt, &base, Some("diloco-sync"))?;
    for s in [1u64, 2, 4] {
        let mut c = base.clone();
        c.apply_arg("staleness", &s.to_string())?;
        exp.run(&rt, &c, Some(&format!("async-s{s}")))?;
    }

    println!("\n=== async DiLoCo: wallclock vs staleness (period {period}) ===\n");
    let sync_step = exp.runs[0].mean_step_time();
    for run in &exp.runs {
        let losses: Vec<f64> = run.steps.iter().map(|r| r.loss).collect();
        println!(
            "{:<14} loss {}  t/step {:>9} ({:>5.2}x)  val {:.4}",
            run.label,
            sparkline(&losses, 32),
            fmt_secs(run.mean_step_time()),
            sync_step / run.mean_step_time(),
            run.final_val_loss().unwrap_or(f64::NAN),
        );
    }
    println!("{}", exp.finish()?);
    println!("CSV series in {}", exp.out_dir.display());
    Ok(())
}
