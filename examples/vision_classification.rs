//! The paper's ViT scenario (Fig 2b): replicator comparison for image
//! classification on the procedural-texture dataset.
//!
//!     cargo run --release --example vision_classification -- --steps 200
//!
//! Paper finding: **DeMo replication wins on ViT** ("fast moving momenta
//! is more suited for this task"); Striding beats Random on highly
//! structured image data.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::replicate::ReplSpec;
use detonation::util::argparse::ArgParser;

fn main() -> Result<()> {
    let args = ArgParser::new("vision_classification", "replicator comparison on ViT")
        .opt("model", "vit-tiny", "artifact name")
        .opt("steps", "200", "training steps")
        .opt("rate", "1/8", "compression rate")
        .flag("quick", "artifact-free CI smoke shape (synthetic-lm, 8 steps)")
        .parse_env();

    let rt = runtime()?;
    let mut exp = Experiment::new("vision_classification", &results_root());
    let rate = args.str("rate").strip_prefix("1/").unwrap_or("8").to_string();
    let quick = args.flag("quick");
    let steps = if quick { 8 } else { args.u64("steps") };

    let base = ExperimentConfig {
        model: if quick {
            "synthetic-lm".into()
        } else {
            args.string("model")
        },
        nodes: 2,
        accels_per_node: 2,
        steps,
        val_every: (steps / 4).max(1),
        // Paper uses 1e-5 for ViT-B; our tiny stand-in tolerates more.
        lr: 5e-4,
        ..Default::default()
    };

    for spec in [
        format!("demo:1/{rate}"),
        format!("random:1/{rate}"),
        format!("striding:1/{rate}"),
        format!("diloco:{rate}"),
    ] {
        let mut cfg = base.clone();
        cfg.repl = ReplSpec::parse(&spec)?;
        exp.run(&rt, &cfg, Some(&cfg.repl.label()))?;
    }

    println!("\n=== image classification (ViT): replicator comparison ===\n");
    println!("{}", exp.finish()?);
    if let Some((label, loss)) = exp.best_val() {
        println!("best validation loss: {label} ({loss:.4})");
        println!("(paper Fig 2b: DeMo replication wins this architecture)");
    }
    Ok(())
}
