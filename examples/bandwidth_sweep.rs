//! Fig 10 scenario: average time per optimization step as the inter-node
//! link is throttled to 10 / 100 / 1000 / 10000 Mbps.
//!
//!     cargo run --release --example bandwidth_sweep
//!
//! Paper findings this reproduces: compression rate dominates below
//! ~500 Mbps; Random-1/32 ≈ 3.33× faster than DeMo-1/32 at 10 Mbps and
//! ≈ 18× faster than Decoupled-AdamW with full replication; Random-1/16
//! tracks DeMo-1/32 (DeMo ships 2× the bytes at equal rate).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::util::argparse::ArgParser;
use detonation::util::fmt_secs;

fn main() -> Result<()> {
    let args = ArgParser::new("bandwidth_sweep", "time/step vs inter-node bandwidth")
        .opt("model", "seq2seq-tiny", "artifact name")
        .opt("steps", "24", "steps per point (timing only)")
        .flag("quick", "artifact-free CI smoke shape (synthetic-lm, 6 steps)")
        .parse_env();
    let quick = args.flag("quick");
    let model = if quick {
        "synthetic-lm".to_string()
    } else {
        args.string("model")
    };
    let steps = if quick { 6 } else { args.u64("steps") };

    let rt = runtime()?;
    let mut exp = Experiment::new("bandwidth_sweep", &results_root());
    let schemes = [
        ("demo-sgd", "demo:1/16"),
        ("demo-sgd", "demo:1/32"),
        ("demo-sgd", "random:1/16"),
        ("demo-sgd", "random:1/32"),
        ("decoupled-adamw", "full:sign"),
    ];
    let bandwidths = [10.0, 100.0, 1000.0, 10000.0];
    // Latency-scaled paper network (T5-Large reference); the model is
    // fixed for the whole sweep, so resolve its size once.
    let params = if quick {
        detonation::runtime::Manifest::synthetic(&model).param_count
    } else {
        let meta = std::fs::read_to_string(format!("artifacts/{model}.meta.json"))?;
        detonation::runtime::Manifest::parse(&meta)?.param_count
    };

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (opt, repl) in schemes {
        let mut times = Vec::new();
        for mbps in bandwidths {
            // Throttle the inter-node link to the sweep point.
            let mut cfg = ExperimentConfig {
                model: model.clone(),
                nodes: 2,
                accels_per_node: 2,
                steps,
                net: detonation::net::NetModel::paper_scaled(params, 737e6)
                    .with_inter_mbps(mbps),
                ..Default::default()
            };
            cfg.apply_arg("opt", opt)?;
            cfg.apply_arg("repl", repl)?;
            let label = format!("{}-{}-{}mbps", opt, cfg.repl.label(), mbps);
            let run = exp.run(&rt, &cfg, Some(&label))?;
            times.push(run.mean_step_time());
        }
        rows.push((format!("{opt}+{repl}"), times));
    }

    println!("\n=== average time per optimization step (simulated) ===\n");
    print!("{:<34}", "scheme");
    for b in bandwidths {
        print!("{:>12}", format!("{b} Mbps"));
    }
    println!();
    for (label, times) in &rows {
        print!("{label:<34}");
        for t in times {
            print!("{:>12}", fmt_secs(*t));
        }
        println!();
    }
    // Headline ratios at 10 Mbps.
    let at10 = |i: usize| rows[i].1[0];
    println!(
        "\nat 10 Mbps: random-1/32 is {:.2}x faster than demo-1/32, {:.1}x faster than full replication",
        at10(1) / at10(3),
        at10(4) / at10(3),
    );
    exp.finish()?;
    Ok(())
}
