//! Fault injection end to end: flaky links healed by retries.
//!
//! Trains the same DiLoCo group four ways on a throttled two-node-pair
//! cluster — a perfect network, a 5% per-attempt packet-drop regime, a
//! lossy *and* corrupting regime, and a degraded link running at a
//! quarter of its bandwidth — and prints what the self-healing transfer
//! layer pays for each: retry counts, checksum-detected corruptions,
//! the number of faulted links, and the simulated time per step.
//!
//!     cargo run --release --example fault_injection
//!
//! Every fault decision is a pure function of `--seed`, the step, the
//! attempt, and the link, so each arm is bit-reproducible. Uses the
//! in-process `synthetic-lm` surrogate, so no artifacts are needed. The
//! same sweep at bench scale writes `BENCH_faults.json`
//! (`cargo bench --bench faults`).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::metrics::sparkline;
use detonation::util::argparse::ArgParser;
use detonation::util::fmt_secs;

fn main() -> Result<()> {
    detonation::util::logging::init();
    let args = ArgParser::new("fault_injection", "flaky-link DiLoCo with self-healing retries")
        .opt("period", "4", "DiLoCo sync period (steps)")
        .opt("steps", "48", "training steps per arm")
        .opt("max-retries", "3", "retry attempts before a sender is treated as late")
        .flag("quick", "CI smoke shape (3 sync windows per arm)")
        .parse_env();
    let period: u64 = args.str("period").parse()?;
    let steps: u64 = if args.flag("quick") {
        3 * period
    } else {
        args.str("steps").parse()?
    };

    let rt = runtime()?;
    let mut exp = Experiment::new("fault_injection", &results_root());

    let base = {
        let mut c = ExperimentConfig {
            model: "synthetic-lm".into(),
            nodes: 4,
            accels_per_node: 1,
            steps,
            lr: 0.02,
            seed: 23,
            val_every: steps,
            val_batches: 8,
            ..Default::default()
        };
        c.apply_arg("inter-mbps", "200")?;
        c.apply_arg("repl", &format!("diloco:{period}"))?;
        c.apply_arg("max-retries", args.str("max-retries"))?;
        c
    };

    let arms: [(&str, &str); 4] = [
        ("perfect", ""),
        ("drop5", "drop:*-*@p0.05"),
        ("flaky", "drop:*-*@p0.2,corrupt:*-*@p0.2"),
        ("degraded", "degrade:1-*@0.25x"),
    ];
    for (label, spec) in arms {
        let mut c = base.clone();
        if !spec.is_empty() {
            c.apply_arg("link-fault", spec)?;
        }
        exp.run(&rt, &c, Some(label))?;
    }

    println!("\n=== DiLoCo under link faults (period {period}, retries + backoff) ===\n");
    let perfect_step = exp.runs[0].mean_step_time();
    for run in &exp.runs {
        let losses: Vec<f64> = run.steps.iter().map(|r| r.loss).collect();
        println!(
            "{:<10} loss {}  t/step {:>9} ({:>5.2}x)  retries {:>3}  corrupt {:>3}  links {:>2}",
            run.label,
            sparkline(&losses, 32),
            fmt_secs(run.mean_step_time()),
            run.mean_step_time() / perfect_step,
            run.total_retries(),
            run.total_corrupt_detected(),
            run.steps.last().map(|r| r.faulted_links).unwrap_or(0),
        );
    }
    println!("{}", exp.finish()?);
    println!("CSV series in {}", exp.out_dir.display());
    Ok(())
}
