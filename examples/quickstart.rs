//! Quickstart: the 60-second tour of FlexDeMo.
//!
//! Trains a tiny causal LM on a 2-node × 2-accelerator simulated cluster
//! twice — once with conventional Hybrid-FSDP + AdamW (full inter-node
//! gradient sync), once with FlexDeMo (DeMo-SGD + DeMo replication at
//! 1/8 compression) — and prints the loss curves, simulated step times,
//! and the inter-node bandwidth each scheme consumed.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have produced `artifacts/lm-tiny.*`;
//! `-- --quick` runs the artifact-free `synthetic-lm` smoke shape
//! instead (what CI executes).

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::metrics::sparkline;
use detonation::util::{fmt_bytes, fmt_secs};

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let rt = runtime()?;
    let mut exp = Experiment::new("quickstart", &results_root());

    let base = ExperimentConfig {
        model: if quick { "synthetic-lm" } else { "lm-tiny" }.into(),
        nodes: 2,
        accels_per_node: 2,
        steps: if quick { 24 } else { 120 },
        val_every: if quick { 8 } else { 40 },
        lr: 2e-3,
        ..Default::default()
    };

    // Conventional baseline: AdamW + full inter-node gradient sync.
    let mut baseline = base.clone();
    baseline.opt = detonation::optim::OptSpec::parse("adamw")?;
    baseline.repl = detonation::replicate::ReplSpec::parse("full")?;
    exp.run(&rt, &baseline, Some("hybrid-fsdp-adamw"))?;

    // FlexDeMo: DeMo-SGD + DeMo replication, 1/8 of the components, signed.
    let mut flex = base.clone();
    flex.opt = detonation::optim::OptSpec::parse("demo-sgd")?;
    flex.repl = detonation::replicate::ReplSpec::parse("demo:1/8")?;
    exp.run(&rt, &flex, Some("flexdemo-1/8"))?;

    println!("\n=== quickstart: FlexDeMo vs conventional Hybrid-FSDP ===\n");
    for run in &exp.runs {
        let losses: Vec<f64> = run.steps.iter().map(|r| r.loss).collect();
        println!(
            "{:<22} loss {}  {:.3} → {:.3}   t/step {:>9}   inter-node {}",
            run.label,
            sparkline(&losses, 40),
            losses.first().unwrap(),
            losses.last().unwrap(),
            fmt_secs(run.mean_step_time()),
            fmt_bytes(run.total_inter_bytes()),
        );
    }
    let (b, f) = (&exp.runs[0], &exp.runs[1]);
    println!(
        "\nFlexDeMo used {:.1}x less inter-node bandwidth and was {:.2}x faster per step.",
        b.total_inter_bytes() as f64 / f.total_inter_bytes() as f64,
        b.mean_step_time() / f.mean_step_time(),
    );
    println!("{}", exp.finish()?);
    println!("CSV series in {}", exp.out_dir.display());
    Ok(())
}
