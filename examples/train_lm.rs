//! End-to-end validation driver (DESIGN.md deliverable): train a causal-LM
//! transformer with the full three-layer stack — Pallas-kernel HLO
//! artifacts executed via PJRT from the Rust FlexDeMo coordinator — for a
//! few hundred steps on the synthetic corpus, logging the loss curve and
//! comparing against the conventional Hybrid-FSDP + AdamW baseline.
//!
//!     cargo run --release --example train_lm -- \
//!         --model lm-small --steps 300 --nodes 2 --accels 2
//!
//! `--model lm-100m` runs the ~100M-parameter config (emit it first:
//! `cd python && python -m compile.aot --out ../artifacts --models lm-100m`).
//! `--baseline` also runs the AdamW/full-sync reference.
//! The recorded run lives in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use detonation::config::ExperimentConfig;
use detonation::coordinator::{results_root, runtime, Experiment};
use detonation::metrics::sparkline;
use detonation::util::argparse::ArgParser;
use detonation::util::{fmt_bytes, fmt_secs};

fn main() -> Result<()> {
    let args = ArgParser::new("train_lm", "end-to-end FlexDeMo LM training")
        .opt("model", "lm-small", "artifact name (lm-tiny|lm-small|lm-100m)")
        .opt("steps", "300", "training steps")
        .opt("nodes", "2", "nodes")
        .opt("accels", "2", "accelerators per node")
        .opt("repl", "demo:1/16", "replication scheme")
        .opt("opt", "demo-sgd", "optimizer")
        .opt("lr", "0.001", "learning rate")
        .opt("warmup", "12", "warmup steps (OLMo-style 4%)")
        .opt("val-every", "50", "validation cadence")
        .flag("baseline", "also run the AdamW + full-sync baseline")
        .flag("quick", "artifact-free CI smoke shape (synthetic-lm, 16 steps)")
        .parse_env();

    let rt = runtime()?;
    let mut exp = Experiment::new("train_lm", &results_root());

    let mut cfg = ExperimentConfig::default();
    for key in ["model", "steps", "nodes", "accels", "repl", "opt", "lr", "warmup", "val-every"] {
        cfg.apply_arg(key, args.str(key))?;
    }
    if args.flag("quick") {
        cfg.model = "synthetic-lm".into();
        cfg.steps = 16;
        cfg.warmup_steps = 2;
        cfg.val_every = 8;
    }

    let t0 = std::time::Instant::now();
    let flex = exp.run(&rt, &cfg, Some("flexdemo"))?;
    let wall = t0.elapsed().as_secs_f64();
    let losses: Vec<f64> = flex.steps.iter().map(|r| r.loss).collect();
    println!("\n=== {} / {} / {} ===", cfg.model, cfg.opt.label(), cfg.repl.label());
    println!("loss curve  {}", sparkline(&losses, 60));
    println!(
        "loss {:.4} -> {:.4}   val {}   sim {}   wall {:.1}s   inter-node {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        flex.final_val_loss()
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "-".into()),
        fmt_secs(flex.total_sim_time()),
        wall,
        fmt_bytes(flex.total_inter_bytes()),
    );

    if args.flag("baseline") {
        let mut b = cfg.clone();
        b.opt = detonation::optim::OptSpec::parse("adamw")?;
        b.repl = detonation::replicate::ReplSpec::parse("full")?;
        exp.run(&rt, &b, Some("hybrid-fsdp-adamw"))?;
        let (fx, bl) = (&exp.runs[0], &exp.runs[1]);
        println!(
            "baseline  loss {:.4}   sim {}   inter-node {}  (FlexDeMo is {:.2}x faster/step, {:.1}x less traffic)",
            bl.final_loss().unwrap(),
            fmt_secs(bl.total_sim_time()),
            fmt_bytes(bl.total_inter_bytes()),
            bl.mean_step_time() / fx.mean_step_time(),
            bl.total_inter_bytes() as f64 / fx.total_inter_bytes() as f64,
        );
    }
    println!("\n{}", exp.finish()?);
    Ok(())
}
