#!/usr/bin/env python3
"""Bench-regression gate: diff freshly regenerated BENCH_*.json against
committed snapshots and fail CI on a throughput regression or a broken
invariant field.

Rebar-style compare (see /root/related/BurntSushi__rebar's METHODOLOGY):
measurements are matched by *name* within each artifact, compared as
ratios against a tolerance, and everything that cannot be compared is
reported rather than silently skipped.

Two layers, both of which must pass:

1. **Invariants** — fields the benches assert while writing the artifact
   (zero steady-state allocations, "drop beats wait" under compute and
   NIC stragglers alike, bit-identity booleans, S >= 1 strictly faster
   than synchronous DiLoCo, the >=2x lane-vectorization floor on the
   gated kernel rows, the chaos bench's graceful-degradation band
   and crash-then-rejoin gap, and the adaptive controller's
   beats-every-uniform-rate and loss-band claims). A bench
   that wrote a violating artifact has already failed its own process,
   but the gate re-checks the *committed* claims so a stale or
   hand-edited snapshot cannot pass review.

2. **Throughput compare** — for every metric in the registry, fresh
   must not be worse than baseline by more than --threshold (default
   15%). Wall-clock metrics are machine-dependent, which is exactly why
   the tolerance exists; simulated metrics are deterministic and should
   never trip the gate unless a schedule regressed. Artifacts whose
   `quick` flags differ between baseline and fresh are skipped (smoke
   sizes are not comparable to full runs), and a missing baseline is a
   note, not a failure — the gate arms itself automatically once
   snapshots are committed (CI uploads every fresh artifact as the
   `bench-json` artifact either way).

Usage:
    python3 scripts/bench_gate.py --baseline-dir bench_baseline --fresh-dir .
    python3 scripts/bench_gate.py --self-test
"""

import argparse
import glob
import json
import os
import sys

# metric registry: artifact stem -> list of
#   (container key, row-match keys (None = container is a plain object),
#    value key, higher_is_better)
METRICS = {
    "kernels": [
        ("rows", ("name",), "speedup", True),
        ("lanes", ("name",), "lane_speedup", True),
    ],
    "compress": [
        ("rows", ("name",), "elements_per_sec", True),
        ("extract", None, "speedup", True),
    ],
    "dct": [("rows", ("name", "chunk"), "elements_per_sec", True)],
    "collectives": [("rows", ("name",), "gb_per_sec", True)],
    "runtime": [("rows", ("model",), "gflops_per_sec", True)],
    "overlap": [("schemes", ("scheme",), "sim_speedup", True)],
    "adaptive": [("arms", ("label",), "sim_step_s", False)],
    "async_diloco": [("arms", ("label",), "sim_step_s", False)],
    "stragglers": [("arms", ("label",), "sim_step_s", False)],
    "chaos": [("arms", ("label",), "sim_step_s", False)],
    "faults": [("arms", ("label",), "sim_step_s", False)],
    "topology": [("arms", ("label",), "sim_step_s", False)],
}

# invariant registry: artifact stem -> list of (dotted field path, expected)
INVARIANTS = {
    "adaptive": [
        ("off_bit_identical", True),
        ("controller_beats_fixed", True),
        ("loss_within_band", True),
    ],
    "kernels": [
        ("collectives_steady_state_allocs", 0),
        ("optimizer_steady_state_allocs", 0),
        ("vector_steady_state_allocs", 0),
    ],
    "compress": [("extract.steady_state_allocs", 0)],
    "stragglers": [
        ("homogeneous_bit_identical_to_pr4_async", True),
        ("drop_beats_wait_under_4x_straggler", True),
        ("partial_beats_wait_under_4x_straggler", True),
        ("drop_beats_wait_under_4x_nic_straggler", True),
    ],
    "chaos": [
        ("membership_masks_tracked", True),
        ("crash_checkpoint_stashed", True),
    ],
    "faults": [
        ("faultfree_identical", True),
        ("retry_beats_resend", True),
        ("partition_completed", True),
    ],
    "topology": [
        ("full_bit_identical", True),
        ("gossip_flat", True),
        ("full_grows", True),
    ],
}

# chaos gate bands. Churn severity is ordered baseline <= mild <= heavy,
# but short stochastic runs jitter, so "graceful degradation" is a
# bounded band, not strict monotonicity: every churned arm's tail loss
# must stay within GRACEFUL_BAND x baseline's. The checkpointed rejoin
# must land within REJOIN_GAP of the uninterrupted run (relative).
CHAOS_GRACEFUL_BAND = 1.5
CHAOS_REJOIN_GAP = 0.5
CHAOS_ARMS = (
    "baseline",
    "churn-mild",
    "churn-heavy",
    "crash-norejoin",
    "crash-rejoin-ckpt",
)

# fault-injection gate bands. A 5% per-attempt loss rate healed by the
# retry lane must keep the tail loss within FAULTS_LOSS_BAND x the
# fault-free baseline's, and timeout/backoff retries must finish a
# flaky-link run strictly sooner per sim step than the naive
# re-send-with-the-next-window strawman.
FAULTS_LOSS_BAND = 1.5
FAULTS_ARMS = (
    "baseline",
    "faultfree",
    "drop5",
    "retry",
    "resend",
    "partition",
)

# sync-topology gate bands. Gossip exchanges are O(degree), so a sparse
# arm's per-step sim time at g = 64 must stay within TOPOLOGY_FLAT_BAND x
# its own g = 4 time (the full-group arm, by contrast, must grow), and a
# sparse arm's tail loss must stay within TOPOLOGY_LOSS_BAND x the
# full-group arm's at the same g (gossip mixes slower, it must not
# diverge).
TOPOLOGY_FLAT_BAND = 1.5
TOPOLOGY_LOSS_BAND = 2.0
TOPOLOGY_GROUPS = (4, 16, 64)
TOPOLOGY_SPARSE = ("ring", "random-pair", "hier2")

# adaptive rate-control gate bands. On the 4x mixed-NIC profile the AIMD
# controller's water-filled per-node rates must make its per-step sim
# time strictly lower than EVERY uniform fixed-rate arm's, while its tail
# loss stays within ADAPTIVE_LOSS_BAND x the uncontrolled fixed-1/8
# baseline's. The off-arm bit-identity boolean is asserted by the bench
# while writing the artifact and re-checked here via INVARIANTS.
ADAPTIVE_LOSS_BAND = 1.5
ADAPTIVE_FIXED_ARMS = ("fixed8", "fixed16", "fixed32")


def lookup(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_invariants(stem, doc):
    """Return a list of violation strings for one artifact."""
    errors = []
    for path, expected in INVARIANTS.get(stem, []):
        got = lookup(doc, path)
        if got is None:
            errors.append(f"{stem}: invariant field {path!r} missing")
        elif got != expected:
            errors.append(f"{stem}: invariant {path} = {got!r}, want {expected!r}")
    errors += computed_invariants(stem, doc)
    return errors


def _num(arm, key, errors, stem, label):
    """Fetch a numeric arm field, reporting (not crashing on) absence."""
    v = arm.get(key)
    if not isinstance(v, (int, float)):
        errors.append(f"{stem}: arm {label!r} missing numeric field {key!r}")
        return None
    return v


# lane rows that must clear the 2x vectorization floor (the tentpole
# kernels: fused optimizer sweep, collective reduce, residual scatter)
GATED_LANE_ROWS = ("fused_decay_step", "collective_reduce", "residual_scatter")


def computed_invariants(stem, doc):
    """Cross-row invariants that need arithmetic, not just field equality."""
    errors = []
    if stem == "kernels":
        lanes = {r.get("name"): r for r in doc.get("lanes", [])}
        for name in GATED_LANE_ROWS:
            row = lanes.get(name)
            if row is None:
                errors.append(f"{stem}: gated lane row {name!r} missing")
                continue
            speedup = _num(row, "lane_speedup", errors, stem, name)
            if speedup is not None and not speedup >= 2.0:
                errors.append(
                    f"{stem}: lane row {name!r} below the 2x vectorization "
                    f"floor (lane_speedup = {speedup})"
                )
        for name, row in lanes.items():
            allocs = _num(row, "vector_allocs_per_iter", errors, stem, name)
            if allocs is not None and allocs != 0:
                errors.append(f"{stem}: lane row {name!r} allocates ({allocs}/iter)")
    if stem == "async_diloco":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        sync = arms.get("diloco-sync")
        if sync is None:
            return [f"{stem}: no diloco-sync baseline arm"]
        sync_step = _num(sync, "sim_step_s", errors, stem, "diloco-sync")
        for label, arm in arms.items():
            s = arm.get("staleness")
            if s is None:
                continue
            if s == 0 and arm.get("val_delta_vs_sync_diloco") not in (0, 0.0):
                errors.append(f"{stem}: S=0 arm is not bit-identical to sync")
            step = _num(arm, "sim_step_s", errors, stem, label)
            if s >= 1 and sync_step is not None and step is not None and not step < sync_step:
                errors.append(f"{stem}: {label} not faster than sync ({step} vs {sync_step})")
    if stem == "stragglers":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        wait = arms.get("severity4-wait")
        if wait is None:
            return [f"{stem}: no severity4-wait arm"]
        wait_t = _num(wait, "sim_time_s", errors, stem, "severity4-wait")
        for policy in ("drop", "partial"):
            label = f"severity4-{policy}"
            arm = arms.get(label)
            if arm is None:
                errors.append(f"{stem}: {label} arm missing")
                continue
            t = _num(arm, "sim_time_s", errors, stem, label)
            dropped = _num(arm, "dropped_syncs", errors, stem, label)
            if wait_t is not None and t is not None and not t < wait_t:
                errors.append(
                    f"{stem}: {policy} not faster than wait under the 4x straggler "
                    f"({t} vs {wait_t})"
                )
            elif dropped is not None and dropped <= 0:
                errors.append(f"{stem}: {label} recorded no late contributions")
        nic_wait = arms.get("nic4-wait")
        nic_drop = arms.get("nic4-drop")
        if nic_wait is None or nic_drop is None:
            errors.append(f"{stem}: nic4-wait/nic4-drop NIC-sweep arms missing")
        else:
            wt = _num(nic_wait, "sim_time_s", errors, stem, "nic4-wait")
            dt = _num(nic_drop, "sim_time_s", errors, stem, "nic4-drop")
            if wt is not None and dt is not None and not dt < wt:
                errors.append(
                    f"{stem}: drop not faster than wait under the 4x NIC "
                    f"straggler ({dt} vs {wt})"
                )
    if stem == "chaos":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        for label in CHAOS_ARMS:
            if label not in arms:
                errors.append(f"{stem}: arm {label!r} missing")
        base = arms.get("baseline")
        if base is None:
            return errors
        base_tail = _num(base, "tail_loss", errors, stem, "baseline")
        if base_tail is None or base_tail <= 0:
            errors.append(f"{stem}: baseline tail_loss unusable ({base_tail!r})")
            return errors
        # graceful degradation: churn/crash never blows the loss out of
        # a bounded band of the fixed-group run
        for label in CHAOS_ARMS[1:]:
            arm = arms.get(label)
            if arm is None:
                continue
            tail = _num(arm, "tail_loss", errors, stem, label)
            if tail is not None and not tail <= base_tail * CHAOS_GRACEFUL_BAND:
                errors.append(
                    f"{stem}: {label} tail loss {tail} outside the "
                    f"{CHAOS_GRACEFUL_BAND}x graceful-degradation band of "
                    f"baseline {base_tail}"
                )
        # checkpointed rejoin: within a bounded gap of the uninterrupted
        # run (the restore is bit-exact for the node's private state; the
        # gap only reflects the steps it sat out)
        rejoin = arms.get("crash-rejoin-ckpt")
        if rejoin is not None:
            tail = _num(rejoin, "tail_loss", errors, stem, "crash-rejoin-ckpt")
            if tail is not None and not abs(tail - base_tail) <= base_tail * CHAOS_REJOIN_GAP:
                errors.append(
                    f"{stem}: crash-rejoin-ckpt tail loss {tail} more than "
                    f"{CHAOS_REJOIN_GAP:.0%} away from baseline {base_tail}"
                )
            if rejoin.get("final_membership") != "1111":
                errors.append(
                    f"{stem}: crash-rejoin-ckpt did not end fully rejoined "
                    f"(final_membership = {rejoin.get('final_membership')!r})"
                )
    if stem == "faults":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        for label in FAULTS_ARMS:
            if label not in arms:
                errors.append(f"{stem}: arm {label!r} missing")
        base = arms.get("baseline")
        if base is None:
            return errors
        base_tail = _num(base, "tail_loss", errors, stem, "baseline")
        retries = _num(base, "retries", errors, stem, "baseline")
        if retries is not None and retries != 0:
            errors.append(f"{stem}: baseline retried on a perfect network ({retries})")
        # loss band: 5% drop healed by retries stays near fault-free loss
        drop5 = arms.get("drop5")
        if drop5 is not None and base_tail is not None and base_tail > 0:
            tail = _num(drop5, "tail_loss", errors, stem, "drop5")
            if tail is not None and not tail <= base_tail * FAULTS_LOSS_BAND:
                errors.append(
                    f"{stem}: drop5 tail loss {tail} outside the "
                    f"{FAULTS_LOSS_BAND}x band of baseline {base_tail}"
                )
            r = _num(drop5, "retries", errors, stem, "drop5")
            if r is not None and r <= 0:
                errors.append(f"{stem}: drop5 arm recorded no retries")
        # self-healing retries strictly beat window-scale re-sends
        retry = arms.get("retry")
        resend = arms.get("resend")
        if retry is not None and resend is not None:
            rt = _num(retry, "sim_step_s", errors, stem, "retry")
            st = _num(resend, "sim_step_s", errors, stem, "resend")
            if rt is not None and st is not None and not rt < st:
                errors.append(
                    f"{stem}: retry not faster than naive resend ({rt} vs {st})"
                )
            c = _num(retry, "corrupt_detected", errors, stem, "retry")
            if c is not None and c <= 0:
                errors.append(f"{stem}: retry arm detected no corruption")
    if stem == "topology":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        for g in TOPOLOGY_GROUPS:
            for topo in ("full",) + TOPOLOGY_SPARSE:
                if f"g{g}-{topo}" not in arms:
                    errors.append(f"{stem}: arm 'g{g}-{topo}' missing")
        g_lo, g_hi = TOPOLOGY_GROUPS[0], TOPOLOGY_GROUPS[-1]
        # gossip scaling: every sparse arm stays flat from g_lo to g_hi…
        for topo in TOPOLOGY_SPARSE:
            lo = arms.get(f"g{g_lo}-{topo}")
            hi = arms.get(f"g{g_hi}-{topo}")
            if lo is None or hi is None:
                continue
            lo_t = _num(lo, "sim_step_s", errors, stem, f"g{g_lo}-{topo}")
            hi_t = _num(hi, "sim_step_s", errors, stem, f"g{g_hi}-{topo}")
            if lo_t is not None and hi_t is not None and lo_t > 0 \
                    and not hi_t <= lo_t * TOPOLOGY_FLAT_BAND:
                errors.append(
                    f"{stem}: {topo} per-step time grew past the "
                    f"{TOPOLOGY_FLAT_BAND}x gossip band from g={g_lo} to "
                    f"g={g_hi} ({lo_t} -> {hi_t})"
                )
        # …while the full-group exchange grows with the group
        full_lo = arms.get(f"g{g_lo}-full")
        full_hi = arms.get(f"g{g_hi}-full")
        if full_lo is not None and full_hi is not None:
            lo_t = _num(full_lo, "sim_step_s", errors, stem, f"g{g_lo}-full")
            hi_t = _num(full_hi, "sim_step_s", errors, stem, f"g{g_hi}-full")
            if lo_t is not None and hi_t is not None and not hi_t > lo_t:
                errors.append(
                    f"{stem}: full-group per-step time did not grow with g "
                    f"({lo_t} -> {hi_t})"
                )
        # loss band: gossip mixes slower but must not diverge from full
        for g in TOPOLOGY_GROUPS:
            full = arms.get(f"g{g}-full")
            if full is None:
                continue
            full_tail = _num(full, "tail_loss", errors, stem, f"g{g}-full")
            if full_tail is None or full_tail <= 0:
                errors.append(f"{stem}: g{g}-full tail_loss unusable ({full_tail!r})")
                continue
            for topo in TOPOLOGY_SPARSE:
                arm = arms.get(f"g{g}-{topo}")
                if arm is None:
                    continue
                tail = _num(arm, "tail_loss", errors, stem, f"g{g}-{topo}")
                if tail is not None and not tail <= full_tail * TOPOLOGY_LOSS_BAND:
                    errors.append(
                        f"{stem}: g{g}-{topo} tail loss {tail} outside the "
                        f"{TOPOLOGY_LOSS_BAND}x band of full {full_tail}"
                    )
    if stem == "adaptive":
        arms = {a.get("label"): a for a in doc.get("arms", [])}
        for label in ADAPTIVE_FIXED_ARMS + ("aimd",):
            if label not in arms:
                errors.append(f"{stem}: arm {label!r} missing")
        aimd = arms.get("aimd")
        if aimd is None:
            return errors
        aimd_step = _num(aimd, "sim_step_s", errors, stem, "aimd")
        # water-filling: per-node rates beat every uniform fixed rate
        for label in ADAPTIVE_FIXED_ARMS:
            arm = arms.get(label)
            if arm is None:
                continue
            step = _num(arm, "sim_step_s", errors, stem, label)
            if aimd_step is not None and step is not None and not aimd_step < step:
                errors.append(
                    f"{stem}: aimd not faster than uniform {label} "
                    f"({aimd_step} vs {step})"
                )
        # ...without giving convergence away vs the uncontrolled spec rate
        base = arms.get("fixed8")
        if base is not None:
            base_tail = _num(base, "tail_loss", errors, stem, "fixed8")
            tail = _num(aimd, "tail_loss", errors, stem, "aimd")
            if base_tail is not None and base_tail > 0 and tail is not None \
                    and not tail <= base_tail * ADAPTIVE_LOSS_BAND:
                errors.append(
                    f"{stem}: aimd tail loss {tail} outside the "
                    f"{ADAPTIVE_LOSS_BAND}x band of fixed8 {base_tail}"
                )
    return errors


def iter_metric_pairs(stem, base, fresh):
    """Yield (unit name, base value, fresh value, higher_is_better)."""
    for container, match_keys, value_key, higher in METRICS.get(stem, []):
        b, f = base.get(container), fresh.get(container)
        if b is None or f is None:
            continue
        if match_keys is None:  # plain object holding the metric
            if value_key in b and value_key in f:
                yield f"{container}.{value_key}", b[value_key], f[value_key], higher
            continue
        index = {tuple(r.get(k) for k in match_keys): r for r in b}
        for r in f:
            key = tuple(r.get(k) for k in match_keys)
            if key in index and value_key in r and value_key in index[key]:
                name = "/".join(str(k) for k in key)
                yield f"{container}[{name}].{value_key}", index[key][value_key], r[value_key], higher


def compare(stem, base, fresh, threshold):
    """Return (regressions, compared_count) for one artifact pair."""
    if base.get("quick") != fresh.get("quick"):
        print(f"  {stem}: quick flags differ (baseline={base.get('quick')}, "
              f"fresh={fresh.get('quick')}) — compare skipped")
        return [], 0
    regressions, compared = [], 0
    for unit, bv, fv, higher in iter_metric_pairs(stem, base, fresh):
        if not isinstance(bv, (int, float)) or not isinstance(fv, (int, float)) or bv <= 0:
            continue
        if not higher and fv <= 0:
            # A cost metric that fell to zero is an improvement (or a
            # degenerate config), never a regression — and has no ratio.
            continue
        compared += 1
        ratio = fv / bv if higher else bv / fv
        if ratio < 1.0 - threshold:
            regressions.append(
                f"{stem}: {unit} regressed {100 * (1 - ratio):.1f}% "
                f"(baseline {bv:.6g}, fresh {fv:.6g})"
            )
    return regressions, compared


def run_gate(baseline_dir, fresh_dir, threshold, require_baseline):
    failures = []
    fresh_paths = sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json")))
    if not fresh_paths:
        print(f"no BENCH_*.json found in {fresh_dir!r} — nothing to gate")
        return 1
    for path in fresh_paths:
        stem = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            fresh = json.load(f)
        print(f"{os.path.basename(path)}:")
        bad = check_invariants(stem, fresh)
        for e in bad:
            print(f"  INVARIANT BROKEN: {e}")
        failures += bad
        base_path = os.path.join(baseline_dir, os.path.basename(path))
        if not os.path.exists(base_path):
            msg = f"  no committed baseline at {base_path} — compare skipped"
            print(msg)
            if require_baseline:
                failures.append(msg.strip())
            continue
        with open(base_path) as f:
            base = json.load(f)
        # Re-check the committed snapshot's own claims too: a stale or
        # hand-edited baseline must not pass review (nor skew the
        # compare with tampered numbers).
        base_bad = [f"baseline {e}" for e in check_invariants(stem, base)]
        for e in base_bad:
            print(f"  INVARIANT BROKEN: {e}")
        failures += base_bad
        regressions, compared = compare(stem, base, fresh, threshold)
        for r in regressions:
            print(f"  REGRESSION: {r}")
        failures += regressions
        if compared and not regressions:
            print(f"  {compared} metric(s) within {threshold:.0%} of baseline")
    if failures:
        print(f"\nbench gate FAILED: {len(failures)} problem(s)")
        return 1
    print("\nbench gate passed")
    return 0


def self_test():
    """Pure-function checks so the gate itself cannot bit-rot silently."""
    k = {
        "quick": True,
        "rows": [{"name": "axpy", "speedup": 2.0}],
        "lanes": [
            {"name": "fused_decay_step", "lane_speedup": 3.0,
             "vector_allocs_per_iter": 0, "gated": True},
            {"name": "collective_reduce", "lane_speedup": 2.4,
             "vector_allocs_per_iter": 0, "gated": True},
            {"name": "residual_scatter", "lane_speedup": 2.1,
             "vector_allocs_per_iter": 0, "gated": True},
        ],
        "collectives_steady_state_allocs": 0,
        "optimizer_steady_state_allocs": 0,
        "vector_steady_state_allocs": 0,
    }
    assert check_invariants("kernels", k) == []
    k_bad = dict(k, optimizer_steady_state_allocs=3)
    assert any("optimizer" in e for e in check_invariants("kernels", k_bad))
    # a gated lane row that slips below the 2x floor fails the gate
    k_slow = json.loads(json.dumps(k))
    k_slow["lanes"][1]["lane_speedup"] = 1.7
    assert any("2x vectorization floor" in e for e in check_invariants("kernels", k_slow))
    # a missing gated row is a violation, not a silent skip
    k_gone = json.loads(json.dumps(k))
    del k_gone["lanes"][2]
    assert any("residual_scatter" in e for e in check_invariants("kernels", k_gone))
    # an allocating lane arm fails even when fast
    k_alloc = json.loads(json.dumps(k))
    k_alloc["lanes"][0]["vector_allocs_per_iter"] = 2.0
    assert any("allocates" in e for e in check_invariants("kernels", k_alloc))

    # higher-is-better regression beyond 15% trips; within 15% passes
    fresh_ok = {"quick": True, "rows": [{"name": "axpy", "speedup": 1.8}]}
    fresh_bad = {"quick": True, "rows": [{"name": "axpy", "speedup": 1.5}]}
    assert compare("kernels", k, fresh_ok, 0.15) == ([], 1)
    regs, n = compare("kernels", k, fresh_bad, 0.15)
    assert n == 1 and len(regs) == 1 and "regressed" in regs[0]

    # lane_speedup compares like any other higher-is-better metric
    lane_fresh = {"quick": True,
                  "lanes": [{"name": "fused_decay_step", "lane_speedup": 2.0}]}
    regs, n = compare("kernels", k, lane_fresh, 0.15)
    assert n == 1 and len(regs) == 1 and "lane_speedup" in regs[0]

    # lower-is-better metrics invert the ratio
    base = {"quick": False, "arms": [{"label": "a", "sim_step_s": 1.0}]}
    slower = {"quick": False, "arms": [{"label": "a", "sim_step_s": 1.3}]}
    regs, n = compare("stragglers", base, slower, 0.15)
    assert n == 1 and len(regs) == 1
    # a cost metric that fell to zero is an improvement, not a 100%
    # regression (and must not divide by zero)
    to_zero = {"quick": False, "arms": [{"label": "a", "sim_step_s": 0.0}]}
    assert compare("stragglers", base, to_zero, 0.15) == ([], 0)

    # quick-flag mismatch skips the compare entirely
    assert compare("kernels", dict(k, quick=False), fresh_bad, 0.15) == ([], 0)

    # straggler computed invariants: drop/partial must beat wait, for
    # degraded compute and for degraded NIC alike
    s = {
        "arms": [
            {"label": "severity4-wait", "sim_time_s": 10.0, "dropped_syncs": 0},
            {"label": "severity4-drop", "sim_time_s": 8.0, "dropped_syncs": 4},
            {"label": "severity4-partial", "sim_time_s": 8.5, "dropped_syncs": 4},
            {"label": "nic4-wait", "sim_time_s": 9.0, "dropped_syncs": 0},
            {"label": "nic4-drop", "sim_time_s": 7.0, "dropped_syncs": 3},
        ],
        "homogeneous_bit_identical_to_pr4_async": True,
        "drop_beats_wait_under_4x_straggler": True,
        "partial_beats_wait_under_4x_straggler": True,
        "drop_beats_wait_under_4x_nic_straggler": True,
    }
    assert check_invariants("stragglers", s) == []
    s_bad = json.loads(json.dumps(s))
    s_bad["arms"][1]["sim_time_s"] = 11.0
    assert any("drop not faster" in e for e in check_invariants("stragglers", s_bad))
    # schema drift (missing field) is a reported violation, not a crash
    s_missing = json.loads(json.dumps(s))
    del s_missing["arms"][2]["sim_time_s"]
    assert any("missing numeric field" in e for e in check_invariants("stragglers", s_missing))
    # the NIC sweep gates too: a wait-beating drop arm is required, and
    # the arms themselves must be present
    s_nic = json.loads(json.dumps(s))
    s_nic["arms"][4]["sim_time_s"] = 9.5
    assert any("4x NIC" in e for e in check_invariants("stragglers", s_nic))
    s_nic_gone = json.loads(json.dumps(s))
    del s_nic_gone["arms"][3]
    assert any("NIC-sweep arms missing" in e for e in check_invariants("stragglers", s_nic_gone))

    # chaos: graceful-degradation band + bounded rejoin gap
    c = {
        "membership_masks_tracked": True,
        "crash_checkpoint_stashed": True,
        "arms": [
            {"label": "baseline", "tail_loss": 1.0, "final_membership": ""},
            {"label": "churn-mild", "tail_loss": 1.1, "final_membership": "1111"},
            {"label": "churn-heavy", "tail_loss": 1.3, "final_membership": "1111"},
            {"label": "crash-norejoin", "tail_loss": 1.2, "final_membership": "1011"},
            {"label": "crash-rejoin-ckpt", "tail_loss": 1.1, "final_membership": "1111"},
        ],
    }
    assert check_invariants("chaos", c) == []
    # a churned arm outside the graceful band trips the gate
    c_blown = json.loads(json.dumps(c))
    c_blown["arms"][2]["tail_loss"] = 1.6
    assert any("graceful-degradation band" in e for e in check_invariants("chaos", c_blown))
    # a rejoin that lands too far from the uninterrupted run trips it
    # (the band is tighter than graceful degradation: 0.5 vs 1.5x)
    c_gap = json.loads(json.dumps(c))
    c_gap["arms"][4]["tail_loss"] = 1.49
    assert check_invariants("chaos", c_gap) == []
    c_gap["arms"][4]["tail_loss"] = 1.51
    assert any("away from baseline" in e for e in check_invariants("chaos", c_gap))
    # ...and so does ending the run without the crasher re-admitted
    c_down = json.loads(json.dumps(c))
    c_down["arms"][4]["final_membership"] = "1011"
    assert any("fully rejoined" in e for e in check_invariants("chaos", c_down))
    # a missing arm or flipped bench-side boolean is a violation
    c_gone = json.loads(json.dumps(c))
    del c_gone["arms"][1]
    assert any("churn-mild" in e for e in check_invariants("chaos", c_gone))
    c_flag = dict(c, crash_checkpoint_stashed=False)
    assert any("crash_checkpoint_stashed" in e for e in check_invariants("chaos", c_flag))

    # faults: loss band under 5% drop, retry beats naive resend, and the
    # bench-side booleans (fault-free bit-identity, partition fallback)
    f = {
        "faultfree_identical": True,
        "retry_beats_resend": True,
        "partition_completed": True,
        "arms": [
            {"label": "baseline", "tail_loss": 1.0, "sim_step_s": 1.0,
             "retries": 0, "corrupt_detected": 0},
            {"label": "faultfree", "tail_loss": 1.0, "sim_step_s": 1.0,
             "retries": 0, "corrupt_detected": 0},
            {"label": "drop5", "tail_loss": 1.2, "sim_step_s": 1.1,
             "retries": 9, "corrupt_detected": 0},
            {"label": "retry", "tail_loss": 1.3, "sim_step_s": 1.4,
             "retries": 40, "corrupt_detected": 6},
            {"label": "resend", "tail_loss": 1.3, "sim_step_s": 3.0,
             "retries": 40, "corrupt_detected": 6},
            {"label": "partition", "tail_loss": 1.4, "sim_step_s": 1.2,
             "retries": 30, "corrupt_detected": 0},
        ],
    }
    assert check_invariants("faults", f) == []
    # a drop5 tail outside the 1.5x loss band trips the gate
    f_blown = json.loads(json.dumps(f))
    f_blown["arms"][2]["tail_loss"] = 1.6
    assert any("band of baseline" in e for e in check_invariants("faults", f_blown))
    # retries no faster than window-scale re-sends trips it too
    f_slow = json.loads(json.dumps(f))
    f_slow["arms"][3]["sim_step_s"] = 3.0
    assert any("naive resend" in e for e in check_invariants("faults", f_slow))
    # a baseline that somehow retried, a retry arm that never saw
    # corruption, a missing arm, and a flipped boolean all fail
    f_retry = json.loads(json.dumps(f))
    f_retry["arms"][0]["retries"] = 2
    assert any("perfect network" in e for e in check_invariants("faults", f_retry))
    f_clean = json.loads(json.dumps(f))
    f_clean["arms"][3]["corrupt_detected"] = 0
    assert any("no corruption" in e for e in check_invariants("faults", f_clean))
    f_gone = json.loads(json.dumps(f))
    del f_gone["arms"][5]
    assert any("partition" in e for e in check_invariants("faults", f_gone))
    f_flag = dict(f, faultfree_identical=False)
    assert any("faultfree_identical" in e for e in check_invariants("faults", f_flag))
    # sim_step_s regressions compare like the other lower-is-better arms
    f_base = {"quick": False, "arms": [{"label": "drop5", "sim_step_s": 1.0}]}
    f_reg = {"quick": False, "arms": [{"label": "drop5", "sim_step_s": 1.3}]}
    regs, n = compare("faults", f_base, f_reg, 0.15)
    assert n == 1 and len(regs) == 1

    # topology: gossip arms flat in g, full grows, loss band vs full
    def topo_doc():
        arms = []
        for g, step in ((4, 1.0), (16, 1.4), (64, 2.2)):
            arms.append({"label": f"g{g}-full", "sim_step_s": step,
                         "tail_loss": 1.0})
            for topo in ("ring", "random-pair", "hier2"):
                arms.append({"label": f"g{g}-{topo}", "sim_step_s": 1.0,
                             "tail_loss": 1.5})
        return {
            "full_bit_identical": True,
            "gossip_flat": True,
            "full_grows": True,
            "arms": arms,
        }

    t = topo_doc()
    assert check_invariants("topology", t) == []
    # a gossip arm whose per-step time grows with g trips the gate
    t_grown = topo_doc()
    for arm in t_grown["arms"]:
        if arm["label"] == "g64-ring":
            arm["sim_step_s"] = 1.8
    assert any("gossip band" in e for e in check_invariants("topology", t_grown))
    # a full-group arm that stopped growing trips it too (the exchange
    # degree is the thing under test)
    t_flat = topo_doc()
    for arm in t_flat["arms"]:
        if arm["label"] == "g64-full":
            arm["sim_step_s"] = 1.0
    assert any("did not grow" in e for e in check_invariants("topology", t_flat))
    # a sparse arm diverging past the loss band fails
    t_diverged = topo_doc()
    for arm in t_diverged["arms"]:
        if arm["label"] == "g16-random-pair":
            arm["tail_loss"] = 2.5
    assert any("band of full" in e for e in check_invariants("topology", t_diverged))
    # a missing arm and a flipped bit-identity boolean are violations
    t_gone = topo_doc()
    t_gone["arms"] = [a for a in t_gone["arms"] if a["label"] != "g16-hier2"]
    assert any("g16-hier2" in e for e in check_invariants("topology", t_gone))
    t_flag = dict(topo_doc(), full_bit_identical=False)
    assert any("full_bit_identical" in e for e in check_invariants("topology", t_flag))
    # sim_step_s regressions compare like the other lower-is-better arms
    t_base = {"quick": False, "arms": [{"label": "g4-ring", "sim_step_s": 1.0}]}
    t_reg = {"quick": False, "arms": [{"label": "g4-ring", "sim_step_s": 1.3}]}
    regs, n = compare("topology", t_base, t_reg, 0.15)
    assert n == 1 and len(regs) == 1

    # adaptive: controller beats every uniform fixed rate, loss band vs
    # the uncontrolled fixed-1/8 baseline, off-arm bit-identity boolean
    ad = {
        "off_bit_identical": True,
        "controller_beats_fixed": True,
        "loss_within_band": True,
        "arms": [
            {"label": "fixed8", "sim_step_s": 2.0, "tail_loss": 1.0},
            {"label": "fixed16", "sim_step_s": 1.5, "tail_loss": 1.2},
            {"label": "fixed32", "sim_step_s": 1.2, "tail_loss": 1.4},
            {"label": "aimd", "sim_step_s": 1.0, "tail_loss": 1.3},
        ],
    }
    assert check_invariants("adaptive", ad) == []
    # an aimd arm no faster than SOME uniform rate trips the gate
    ad_slow = json.loads(json.dumps(ad))
    ad_slow["arms"][3]["sim_step_s"] = 1.2
    assert any("not faster than uniform" in e for e in check_invariants("adaptive", ad_slow))
    # an aimd tail outside the 1.5x band of the fixed-1/8 baseline fails
    ad_lossy = json.loads(json.dumps(ad))
    ad_lossy["arms"][3]["tail_loss"] = 1.6
    assert any("band of fixed8" in e for e in check_invariants("adaptive", ad_lossy))
    # a missing arm and a flipped bit-identity boolean are violations
    ad_gone = json.loads(json.dumps(ad))
    del ad_gone["arms"][1]
    assert any("fixed16" in e for e in check_invariants("adaptive", ad_gone))
    ad_flag = dict(ad, off_bit_identical=False)
    assert any("off_bit_identical" in e for e in check_invariants("adaptive", ad_flag))
    # schema drift (missing field) is a reported violation, not a crash
    ad_missing = json.loads(json.dumps(ad))
    del ad_missing["arms"][3]["tail_loss"]
    assert any("missing numeric field" in e for e in check_invariants("adaptive", ad_missing))
    # sim_step_s regressions compare like the other lower-is-better arms
    ad_base = {"quick": False, "arms": [{"label": "aimd", "sim_step_s": 1.0}]}
    ad_reg = {"quick": False, "arms": [{"label": "aimd", "sim_step_s": 1.3}]}
    regs, n = compare("adaptive", ad_base, ad_reg, 0.15)
    assert n == 1 and len(regs) == 1

    # async_diloco: S >= 1 must be faster than sync, S = 0 bit-identical
    a = {
        "arms": [
            {"label": "diloco-sync", "staleness": None, "sim_step_s": 2.0},
            {"label": "async-diloco-s0", "staleness": 0, "sim_step_s": 2.0,
             "val_delta_vs_sync_diloco": 0.0},
            {"label": "async-diloco-s2", "staleness": 2, "sim_step_s": 1.5},
        ]
    }
    assert check_invariants("async_diloco", a) == []
    a["arms"][2]["sim_step_s"] = 2.5
    assert any("not faster" in e for e in check_invariants("async_diloco", a))

    print("bench_gate self-test passed")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default="bench_baseline",
                    help="directory holding the committed BENCH_*.json snapshots")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly regenerated artifacts")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="maximum tolerated fractional regression (default 0.15)")
    ap.add_argument("--require-baseline", action="store_true",
                    help="fail when a fresh artifact has no committed baseline")
    ap.add_argument("--self-test", action="store_true",
                    help="run the gate's own unit checks and exit")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    sys.exit(run_gate(args.baseline_dir, args.fresh_dir, args.threshold,
                      args.require_baseline))


if __name__ == "__main__":
    main()
