#!/usr/bin/env python3
"""Assemble EXPERIMENTS.md sections from results/<fig>/summary.txt.

Run after `cargo bench --bench figures`:
    python scripts/collect_experiments.py >> EXPERIMENTS.md
(or redirect to a file and splice). Keeps EXPERIMENTS.md honest: every
number in the per-figure sections is the verbatim bench output.
"""
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..", "results")

ORDER = [
    "fig1", "fig2a", "fig2b", "fig3", "fig5", "fig7", "fig8", "fig9",
    "fig10a-t5", "fig10b-vit", "fig11", "fig13",
]


def main() -> None:
    for fig in ORDER:
        d = os.path.join(ROOT, fig)
        summary = os.path.join(d, "summary.txt")
        traffic = os.path.join(d, "traffic.txt")
        print(f"\n## §{fig}\n")
        if os.path.exists(summary):
            print("```")
            print(open(summary).read().rstrip())
            print("```")
        elif os.path.exists(traffic):
            print("```")
            print(open(traffic).read().rstrip())
            print("```")
        else:
            print(f"(no results for {fig} — run `cargo bench --bench figures -- {fig.split('-')[0]}`)")


if __name__ == "__main__":
    sys.exit(main())
